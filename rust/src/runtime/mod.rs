//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` (Layer 2 JAX functions wrapping the Layer 1
//! Pallas kernels) and executes them from the Rust hot path.
//!
//! The whole PJRT path is gated behind the off-by-default `xla` cargo
//! feature so the standard build is dependency-light and works offline.
//! Without the feature, [`XlaKernels`] is an inert stub: `load` always
//! fails and `artifacts_present` is `false`, so every caller takes the
//! native bloom-probe / priority-score fallbacks (which are asserted
//! bit-identical to the kernels by the parity tests when the feature is
//! enabled).
//!
//! The interchange format is HLO **text** — jax ≥ 0.5 emits serialized
//! protos with 64-bit instruction ids that the pinned xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md
//! and python/compile/aot.py).
//!
//! Shapes are fixed at AOT time and padded by the callers here; the
//! constants below must match `python/compile/model.py`.

use anyhow::Result;

/// Batch of fingerprints per bloom-probe call (`model.BLOOM_BATCH`).
pub const BLOOM_BATCH: usize = 128;
/// Padded filter size in u32 words (`model.BLOOM_WORDS`). Filters larger
/// than this fall back to the native prober.
pub const BLOOM_WORDS: usize = 8192;
/// Padded SST count per priority-scoring call (`model.PRIORITY_N`).
pub const PRIORITY_N: usize = 1024;

/// Compiled XLA executables backing the two kernel entry points.
#[cfg(feature = "xla")]
pub struct XlaKernels {
    client: xla::PjRtClient,
    bloom: xla::PjRtLoadedExecutable,
    priority: xla::PjRtLoadedExecutable,
    /// Wall-clock dispatch counters (perf accounting, EXPERIMENTS.md §Perf).
    pub bloom_calls: std::cell::Cell<u64>,
    pub priority_calls: std::cell::Cell<u64>,
}

#[cfg(feature = "xla")]
impl XlaKernels {
    /// Load both kernels from `dir` (normally `artifacts/`). Returns an
    /// error if the artifacts are missing — callers treat that as "run
    /// with native kernels".
    pub fn load(dir: &str) -> Result<Self> {
        use anyhow::Context;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let bloom = Self::compile(&client, &format!("{dir}/bloom_probe.hlo.txt"))?;
        let priority = Self::compile(&client, &format!("{dir}/priority.hlo.txt"))?;
        Ok(XlaKernels {
            client,
            bloom,
            priority,
            bloom_calls: std::cell::Cell::new(0),
            priority_calls: std::cell::Cell::new(0),
        })
    }

    /// True if the artifact files exist (cheap check before `load`).
    pub fn artifacts_present(dir: &str) -> bool {
        use std::path::Path;
        Path::new(&format!("{dir}/bloom_probe.hlo.txt")).exists()
            && Path::new(&format!("{dir}/priority.hlo.txt")).exists()
    }

    fn compile(client: &xla::PjRtClient, path: &str) -> Result<xla::PjRtLoadedExecutable> {
        use anyhow::Context;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("load HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client.compile(&comp).with_context(|| format!("compile {path}"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Probe `fps` (≤ BLOOM_BATCH fingerprints) against one Bloom filter
    /// given as `words` (≤ BLOOM_WORDS u32 words) with `nbits` live bits
    /// and `k` probes. Returns one bool per input fingerprint.
    pub fn bloom_probe(&self, fps: &[u32], words: &[u32], nbits: u32, k: u32) -> Result<Vec<bool>> {
        anyhow::ensure!(fps.len() <= BLOOM_BATCH, "fps batch too large");
        anyhow::ensure!(words.len() <= BLOOM_WORDS, "filter too large for AOT shape");
        let mut fps_pad = [0u32; BLOOM_BATCH];
        fps_pad[..fps.len()].copy_from_slice(fps);
        let mut words_pad = vec![0u32; BLOOM_WORDS];
        words_pad[..words.len()].copy_from_slice(words);
        let x_fps = xla::Literal::vec1(&fps_pad[..]);
        let x_words = xla::Literal::vec1(&words_pad);
        let x_nbits = xla::Literal::scalar(nbits);
        let x_k = xla::Literal::scalar(k);
        let result = self
            .bloom
            .execute::<xla::Literal>(&[x_fps, x_words, x_nbits, x_k])?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        let hits = out.to_vec::<i32>()?;
        self.bloom_calls.set(self.bloom_calls.get() + 1);
        Ok(hits[..fps.len()].iter().map(|&h| h != 0).collect())
    }

    /// Score up to PRIORITY_N SSTs: `score = -level * 1e12 + reads / age`
    /// (§3.4 priorities; identical to `crate::policy::priority_score`,
    /// computed in f64 by the kernel for read-rate tie-break resolution).
    pub fn priority_scores(
        &self,
        levels: &[i32],
        reads: &[f32],
        ages_s: &[f32],
    ) -> Result<Vec<f64>> {
        let n = levels.len();
        anyhow::ensure!(n == reads.len() && n == ages_s.len(), "length mismatch");
        anyhow::ensure!(n <= PRIORITY_N, "too many SSTs for AOT shape");
        let mut l = vec![0i32; PRIORITY_N];
        let mut r = vec![0f32; PRIORITY_N];
        let mut a = vec![1f32; PRIORITY_N];
        l[..n].copy_from_slice(levels);
        r[..n].copy_from_slice(reads);
        a[..n].copy_from_slice(ages_s);
        let result = self
            .priority
            .execute::<xla::Literal>(&[
                xla::Literal::vec1(&l),
                xla::Literal::vec1(&r),
                xla::Literal::vec1(&a),
            ])?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        let scores = out.to_vec::<f64>()?;
        self.priority_calls.set(self.priority_calls.get() + 1);
        Ok(scores[..n].to_vec())
    }
}

/// Inert stand-in compiled when the `xla` feature is off: keeps the type
/// (and therefore `Engine::attach_xla`, `HhzsPolicy::with_scorer`, and the
/// batched read path) available while guaranteeing the native fallbacks
/// run. `load` always fails, so no instance can ever be constructed.
#[cfg(not(feature = "xla"))]
pub struct XlaKernels {
    /// Wall-clock dispatch counters (always zero without the feature).
    pub bloom_calls: std::cell::Cell<u64>,
    pub priority_calls: std::cell::Cell<u64>,
}

#[cfg(not(feature = "xla"))]
impl XlaKernels {
    /// Always fails: this build does not link a PJRT runtime.
    pub fn load(_dir: &str) -> Result<Self> {
        anyhow::bail!(
            "built without the `xla` cargo feature — rebuild with \
             `--features xla` (and a real PJRT binding) to load AOT kernels"
        )
    }

    /// Always false without the feature, so callers skip to native paths.
    pub fn artifacts_present(_dir: &str) -> bool {
        false
    }

    pub fn platform(&self) -> String {
        "native-fallback".to_string()
    }

    /// Unreachable in practice (no instance can exist); present for API
    /// parity with the feature-enabled build.
    pub fn bloom_probe(
        &self,
        _fps: &[u32],
        _words: &[u32],
        _nbits: u32,
        _k: u32,
    ) -> Result<Vec<bool>> {
        anyhow::bail!("bloom kernel unavailable: built without the `xla` feature")
    }

    pub fn priority_scores(
        &self,
        _levels: &[i32],
        _reads: &[f32],
        _ages_s: &[f32],
    ) -> Result<Vec<f64>> {
        anyhow::bail!("priority kernel unavailable: built without the `xla` feature")
    }
}

#[cfg(test)]
mod tests {
    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_reports_unavailable() {
        use super::XlaKernels;
        assert!(!XlaKernels::artifacts_present("artifacts"));
        // (match, not unwrap_err: the stub deliberately has no Debug impl)
        let err = match XlaKernels::load("artifacts") {
            Err(e) => e.to_string(),
            Ok(_) => panic!("stub load must fail"),
        };
        assert!(err.contains("xla"), "load error should name the feature: {err}");
    }

    #[cfg(feature = "xla")]
    mod parity {
        use super::super::*;
        use crate::lsm::Bloom;
        use crate::policy::priority_score;
        use crate::sim::rng::fingerprint32;

        fn kernels() -> Option<XlaKernels> {
            if !XlaKernels::artifacts_present("artifacts") {
                eprintln!("skipping XLA test: artifacts/ not built (run `make artifacts`)");
                return None;
            }
            Some(XlaKernels::load("artifacts").expect("load artifacts"))
        }

        #[test]
        fn bloom_parity_with_native() {
            let Some(k) = kernels() else { return };
            let fps: Vec<u32> = (0..1000u64).map(|i| fingerprint32(&i.to_be_bytes())).collect();
            let bloom = Bloom::build(&fps, 10);
            assert!(bloom.words().len() <= BLOOM_WORDS);
            // Probe a mix of present and absent fingerprints.
            let probes: Vec<u32> =
                (0..64u64).map(|i| fingerprint32(&(i * 37 + 1).to_be_bytes())).collect();
            let xla_hits =
                k.bloom_probe(&probes, bloom.words(), bloom.nbits(), bloom.k()).unwrap();
            for (i, fp) in probes.iter().enumerate() {
                assert_eq!(
                    xla_hits[i],
                    bloom.may_contain(*fp),
                    "parity mismatch at fp {fp:#x}"
                );
            }
        }

        #[test]
        fn bloom_no_false_negatives_via_xla() {
            let Some(k) = kernels() else { return };
            let fps: Vec<u32> = (0..500u64).map(|i| fingerprint32(&i.to_be_bytes())).collect();
            let bloom = Bloom::build(&fps, 10);
            let hits =
                k.bloom_probe(&fps[..128], bloom.words(), bloom.nbits(), bloom.k()).unwrap();
            assert!(hits.iter().all(|&h| h), "XLA prober must not produce false negatives");
        }

        #[test]
        fn priority_parity_with_native() {
            let Some(k) = kernels() else { return };
            let levels = vec![0i32, 1, 2, 3, 3, 4];
            let reads = vec![10f32, 200.0, 5.0, 1000.0, 10.0, 0.0];
            let ages = vec![1f32, 2.0, 1.0, 4.0, 1.0, 10.0];
            let scores = k.priority_scores(&levels, &reads, &ages).unwrap();
            for i in 0..levels.len() {
                let native =
                    priority_score(levels[i] as usize, reads[i] as f64 / ages[i] as f64);
                let rel = (scores[i] - native).abs() / native.abs().max(1.0);
                assert!(rel < 1e-9, "i={i} xla={} native={}", scores[i], native);
            }
            // Ordering agrees: L3 with 250 IOPS beats L3 with 10 IOPS; any
            // L2 beats any L3.
            assert!(scores[3] > scores[4]);
            assert!(scores[2] > scores[3]);
        }

        #[test]
        fn oversized_inputs_rejected() {
            let Some(k) = kernels() else { return };
            let big = vec![0u32; BLOOM_BATCH + 1];
            assert!(k.bloom_probe(&big, &[0u32; 4], 128, 6).is_err());
            let levels = vec![0i32; PRIORITY_N + 1];
            let f = vec![0f32; PRIORITY_N + 1];
            assert!(k.priority_scores(&levels, &f, &f).is_err());
        }
    }
}
