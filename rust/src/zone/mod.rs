//! Zoned block devices: append-only zones with write pointers, reset
//! semantics, and RAM-backed data — the substrate the paper's ZNS SSD and
//! HM-SMR HDD expose (§2.1).
//!
//! The simulator enforces the zoned-storage contract: a zone can be read at
//! any offset below the write pointer, written only *at* the write pointer,
//! and must be reset before its space is reused. Violations are hard errors
//! — the LSM/zenfs layers above are required to be zone-correct, exactly as
//! a host-managed device would require.
//!
//! Zone contents are [`WireBuf`]s: the write pointer, capacities, and all
//! states advance by *logical* bytes (bit-identical to byte-backed zones),
//! while resident memory is the compact physical form — value payloads
//! cost zero bytes of RAM no matter the configured value size.

mod device;

pub use device::{ZoneStats, ZonedDevice};

use crate::wire::WireBuf;

/// Which physical device a zone (or file extent) lives on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Dev {
    Ssd,
    Hdd,
}

impl Dev {
    pub fn name(self) -> &'static str {
        match self {
            Dev::Ssd => "ssd",
            Dev::Hdd => "hdd",
        }
    }
}

/// Zone index within one device.
pub type ZoneId = u32;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ZoneState {
    Empty,
    Open,
    Full,
}

/// One append-only zone with RAM-backed (compact) contents.
#[derive(Clone, Debug)]
pub struct Zone {
    pub capacity: u64,
    wp: u64,
    state: ZoneState,
    data: WireBuf,
    /// Number of resets this zone has seen (wear accounting).
    pub reset_count: u64,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ZoneError {
    NotAtWritePointer { wp: u64, offset: u64 },
    CapacityExceeded { wp: u64, len: u64, capacity: u64 },
    ReadPastWp { wp: u64, offset: u64, len: u64 },
    NotEmpty,
}

impl std::fmt::Display for ZoneError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ZoneError::NotAtWritePointer { wp, offset } => {
                write!(f, "write at offset {offset} but write pointer is {wp}")
            }
            ZoneError::CapacityExceeded { wp, len, capacity } => {
                write!(f, "append of {len} bytes at wp {wp} exceeds capacity {capacity}")
            }
            ZoneError::ReadPastWp { wp, offset, len } => {
                write!(f, "read [{offset}, {offset}+{len}) past write pointer {wp}")
            }
            ZoneError::NotEmpty => write!(f, "zone not empty"),
        }
    }
}

impl std::error::Error for ZoneError {}

impl Zone {
    pub fn new(capacity: u64) -> Self {
        Zone { capacity, wp: 0, state: ZoneState::Empty, data: WireBuf::new(), reset_count: 0 }
    }

    pub fn wp(&self) -> u64 {
        self.wp
    }

    pub fn state(&self) -> ZoneState {
        self.state
    }

    pub fn remaining(&self) -> u64 {
        self.capacity - self.wp
    }

    pub fn is_empty(&self) -> bool {
        self.state == ZoneState::Empty
    }

    /// Physically resident bytes of this zone's contents.
    pub fn phys_bytes(&self) -> u64 {
        self.data.phys_len() as u64
    }

    fn check_append(&self, len: u64) -> Result<(), ZoneError> {
        if self.state == ZoneState::Full || self.wp + len > self.capacity {
            return Err(ZoneError::CapacityExceeded {
                wp: self.wp,
                len,
                capacity: self.capacity,
            });
        }
        Ok(())
    }

    fn commit_append(&mut self, len: u64) -> u64 {
        let off = self.wp;
        self.wp += len;
        self.state = if self.wp == self.capacity { ZoneState::Full } else { ZoneState::Open };
        off
    }

    /// Append raw bytes at the write pointer. Returns the landing offset.
    pub fn append(&mut self, buf: &[u8]) -> Result<u64, ZoneError> {
        self.check_append(buf.len() as u64)?;
        self.data.push_bytes(buf);
        Ok(self.commit_append(buf.len() as u64))
    }

    /// Append a wire buffer (its *logical* length advances the write
    /// pointer; only its physical bytes land in RAM).
    pub fn append_wire(&mut self, buf: &WireBuf) -> Result<u64, ZoneError> {
        self.check_append(buf.len())?;
        self.data.append_buf(buf);
        Ok(self.commit_append(buf.len()))
    }

    /// Explicitly transition Open → Full (the ZNS "finish zone" command).
    pub fn finish(&mut self) {
        if self.state == ZoneState::Open {
            self.state = ZoneState::Full;
        }
    }

    /// Read any range below the write pointer.
    pub fn read(&self, offset: u64, len: u64) -> Result<WireBuf, ZoneError> {
        if offset + len > self.wp {
            return Err(ZoneError::ReadPastWp { wp: self.wp, offset, len });
        }
        Ok(self.data.slice_to_buf(offset, len))
    }

    /// Reset: rewind the write pointer, discard contents, free RAM.
    pub fn reset(&mut self) {
        self.wp = 0;
        self.state = ZoneState::Empty;
        self.data = WireBuf::new();
        self.reset_count += 1;
    }

    /// Model physical power loss during an in-flight append: the write
    /// pointer lands at `at` (clamped to the current wp) and every byte past
    /// it is gone. `at` may fall mid-record — the surviving prefix is a real
    /// on-media torn state and decoding it stops at the tear (the WireBuf
    /// truncation contract). Returns the new write pointer.
    pub fn power_loss_truncate(&mut self, at: u64) -> u64 {
        let at = at.min(self.wp);
        self.data = self.data.slice_to_buf(0, at);
        self.wp = at;
        self.state = if at == 0 {
            ZoneState::Empty
        } else if at == self.capacity {
            ZoneState::Full
        } else {
            ZoneState::Open
        };
        at
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::Payload;

    #[test]
    fn append_advances_wp() {
        let mut z = Zone::new(100);
        assert_eq!(z.append(&[1, 2, 3]).unwrap(), 0);
        assert_eq!(z.append(&[4, 5]).unwrap(), 3);
        assert_eq!(z.wp(), 5);
        assert_eq!(z.state(), ZoneState::Open);
    }

    #[test]
    fn append_past_capacity_rejected() {
        let mut z = Zone::new(4);
        assert!(z.append(&[0; 5]).is_err());
        z.append(&[0; 4]).unwrap();
        assert_eq!(z.state(), ZoneState::Full);
        assert!(z.append(&[1]).is_err());
    }

    #[test]
    fn read_below_wp_only() {
        let mut z = Zone::new(16);
        z.append(b"hello").unwrap();
        assert_eq!(z.read(0, 5).unwrap().phys_bytes(), b"hello");
        assert_eq!(z.read(1, 3).unwrap().phys_bytes(), b"ell");
        assert!(z.read(0, 6).is_err());
    }

    #[test]
    fn reset_rewinds_and_frees() {
        let mut z = Zone::new(16);
        z.append(b"0123456789abcdef").unwrap();
        assert_eq!(z.state(), ZoneState::Full);
        z.reset();
        assert_eq!(z.state(), ZoneState::Empty);
        assert_eq!(z.wp(), 0);
        assert_eq!(z.reset_count, 1);
        // Space reusable after reset.
        z.append(b"x").unwrap();
        assert_eq!(z.read(0, 1).unwrap().phys_bytes(), b"x");
    }

    #[test]
    fn finish_marks_full_and_rejects_appends() {
        let mut z = Zone::new(16);
        z.append(b"abc").unwrap();
        z.finish();
        assert_eq!(z.state(), ZoneState::Full);
        assert!(z.append(b"d").is_err());
        // Reads of written data still work on a finished zone.
        assert_eq!(z.read(0, 3).unwrap().phys_bytes(), b"abc");
    }

    #[test]
    fn power_loss_truncate_tears_mid_record() {
        let mut z = Zone::new(10_000);
        let mut rec = WireBuf::new();
        rec.push_entry(b"key-a", 1, Some(Payload::fill(1, 100)));
        let first = rec.len();
        z.append_wire(&rec).unwrap();
        let mut rec2 = WireBuf::new();
        rec2.push_entry(b"key-b", 2, Some(Payload::fill(2, 100)));
        z.append_wire(&rec2).unwrap();
        // Power fails mid-way through the second record.
        let tear = first + rec2.len() / 2;
        assert_eq!(z.power_loss_truncate(tear), tear);
        assert_eq!(z.wp(), tear);
        assert_eq!(z.state(), ZoneState::Open);
        // Survivor decodes the intact first record only; the torn tail
        // stops decoding instead of producing garbage.
        let back = z.read(0, z.wp()).unwrap();
        let es: Vec<_> = back.entries().collect();
        assert_eq!(es.len(), 1);
        assert_eq!(es[0].key.to_vec(), b"key-a");
        // Truncating to zero empties the zone; clamping past wp is a no-op.
        assert_eq!(z.power_loss_truncate(0), 0);
        assert_eq!(z.state(), ZoneState::Empty);
        assert_eq!(z.power_loss_truncate(999_999), 0);
    }

    #[test]
    fn power_loss_truncate_mid_key_run_preserves_dehydrated_state() {
        // A zone holding a dehydrated buffer loses power mid-way through
        // an elided entry head: the surviving prefix must stay paged
        // (contained key runs intact, the cut head materialized) and
        // hydrate to exactly the torn plain bytes.
        let mut plain = WireBuf::new();
        for i in 0..6u64 {
            plain.push_entry(&crate::ycsb::key_for(i, 24), i, Some(Payload::fill(2, 80)));
        }
        let paged = plain.dehydrate_copy().unwrap();
        let mut z = Zone::new(10_000);
        z.append_wire(&paged).unwrap();
        assert_eq!(z.phys_bytes(), 0);
        // Tear inside the 4th entry's (elided) head.
        let tear = paged.key_runs()[3].log_off + 20;
        z.power_loss_truncate(tear);
        assert_eq!(z.wp(), tear);
        let mut back = z.read(0, tear).unwrap();
        assert_eq!(back.key_runs().len(), 3, "contained runs survive the tear");
        back.hydrate();
        assert_eq!(back, plain.slice_to_buf(0, tear));
        // The intact entries still decode; the torn head stops decode.
        assert_eq!(back.entries().count(), 3);
    }

    #[test]
    fn wire_append_advances_wp_logically_but_stores_compactly() {
        let mut z = Zone::new(10_000);
        let mut rec = WireBuf::new();
        rec.push_entry(b"user00000001", 7, Some(Payload::fill(3, 1000)));
        let off = z.append_wire(&rec).unwrap();
        assert_eq!(off, 0);
        assert_eq!(z.wp(), rec.len(), "wp advances by logical bytes");
        assert!(z.phys_bytes() < 64, "payload bytes must not be resident");
        // Round trip through a zone read.
        let back = z.read(0, rec.len()).unwrap();
        let e = back.entries().next().unwrap();
        assert_eq!(e.key.to_vec(), b"user00000001");
        assert_eq!(e.value, Some(Payload::fill(3, 1000)));
        // Capacity is enforced on logical size.
        let mut big = WireBuf::new();
        big.push_entry(b"k", 8, Some(Payload::fill(0, 20_000)));
        assert!(z.append_wire(&big).is_err());
    }
}
