//! Zoned block devices: append-only zones with write pointers, reset
//! semantics, and RAM-backed data — the substrate the paper's ZNS SSD and
//! HM-SMR HDD expose (§2.1).
//!
//! The simulator enforces the zoned-storage contract: a zone can be read at
//! any offset below the write pointer, written only *at* the write pointer,
//! and must be reset before its space is reused. Violations are hard errors
//! — the LSM/zenfs layers above are required to be zone-correct, exactly as
//! a host-managed device would require.

mod device;

pub use device::{ZoneStats, ZonedDevice};



/// Which physical device a zone (or file extent) lives on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Dev {
    Ssd,
    Hdd,
}

impl Dev {
    pub fn name(self) -> &'static str {
        match self {
            Dev::Ssd => "ssd",
            Dev::Hdd => "hdd",
        }
    }
}

/// Zone index within one device.
pub type ZoneId = u32;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ZoneState {
    Empty,
    Open,
    Full,
}

/// One append-only zone with RAM-backed contents.
#[derive(Clone, Debug)]
pub struct Zone {
    pub capacity: u64,
    wp: u64,
    state: ZoneState,
    data: Vec<u8>,
    /// Number of resets this zone has seen (wear accounting).
    pub reset_count: u64,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ZoneError {
    NotAtWritePointer { wp: u64, offset: u64 },
    CapacityExceeded { wp: u64, len: u64, capacity: u64 },
    ReadPastWp { wp: u64, offset: u64, len: u64 },
    NotEmpty,
}

impl std::fmt::Display for ZoneError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ZoneError::NotAtWritePointer { wp, offset } => {
                write!(f, "write at offset {offset} but write pointer is {wp}")
            }
            ZoneError::CapacityExceeded { wp, len, capacity } => {
                write!(f, "append of {len} bytes at wp {wp} exceeds capacity {capacity}")
            }
            ZoneError::ReadPastWp { wp, offset, len } => {
                write!(f, "read [{offset}, {offset}+{len}) past write pointer {wp}")
            }
            ZoneError::NotEmpty => write!(f, "zone not empty"),
        }
    }
}

impl std::error::Error for ZoneError {}

impl Zone {
    pub fn new(capacity: u64) -> Self {
        Zone { capacity, wp: 0, state: ZoneState::Empty, data: Vec::new(), reset_count: 0 }
    }

    pub fn wp(&self) -> u64 {
        self.wp
    }

    pub fn state(&self) -> ZoneState {
        self.state
    }

    pub fn remaining(&self) -> u64 {
        self.capacity - self.wp
    }

    pub fn is_empty(&self) -> bool {
        self.state == ZoneState::Empty
    }

    /// Append at the write pointer. Returns the offset the data landed at.
    pub fn append(&mut self, buf: &[u8]) -> Result<u64, ZoneError> {
        let len = buf.len() as u64;
        if self.state == ZoneState::Full {
            return Err(ZoneError::CapacityExceeded { wp: self.wp, len, capacity: self.capacity });
        }
        if self.wp + len > self.capacity {
            return Err(ZoneError::CapacityExceeded { wp: self.wp, len, capacity: self.capacity });
        }
        let off = self.wp;
        if self.data.capacity() == 0 {
            // Reserve the zone once: WAL-style many-small-appends would
            // otherwise pay O(log n) grow-and-copy cycles per zone.
            self.data.reserve_exact(self.capacity as usize);
        }
        self.data.extend_from_slice(buf);
        self.wp += len;
        self.state = if self.wp == self.capacity { ZoneState::Full } else { ZoneState::Open };
        Ok(off)
    }

    /// Explicitly transition Open → Full (the ZNS "finish zone" command).
    pub fn finish(&mut self) {
        if self.state == ZoneState::Open {
            self.state = ZoneState::Full;
        }
    }

    /// Read any range below the write pointer.
    pub fn read(&self, offset: u64, len: u64) -> Result<&[u8], ZoneError> {
        if offset + len > self.wp {
            return Err(ZoneError::ReadPastWp { wp: self.wp, offset, len });
        }
        Ok(&self.data[offset as usize..(offset + len) as usize])
    }

    /// Reset: rewind the write pointer, discard contents, free RAM.
    pub fn reset(&mut self) {
        self.wp = 0;
        self.state = ZoneState::Empty;
        self.data = Vec::new();
        self.reset_count += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_advances_wp() {
        let mut z = Zone::new(100);
        assert_eq!(z.append(&[1, 2, 3]).unwrap(), 0);
        assert_eq!(z.append(&[4, 5]).unwrap(), 3);
        assert_eq!(z.wp(), 5);
        assert_eq!(z.state(), ZoneState::Open);
    }

    #[test]
    fn append_past_capacity_rejected() {
        let mut z = Zone::new(4);
        assert!(z.append(&[0; 5]).is_err());
        z.append(&[0; 4]).unwrap();
        assert_eq!(z.state(), ZoneState::Full);
        assert!(z.append(&[1]).is_err());
    }

    #[test]
    fn read_below_wp_only() {
        let mut z = Zone::new(16);
        z.append(b"hello").unwrap();
        assert_eq!(z.read(0, 5).unwrap(), b"hello");
        assert_eq!(z.read(1, 3).unwrap(), b"ell");
        assert!(z.read(0, 6).is_err());
    }

    #[test]
    fn reset_rewinds_and_frees() {
        let mut z = Zone::new(16);
        z.append(b"0123456789abcdef").unwrap();
        assert_eq!(z.state(), ZoneState::Full);
        z.reset();
        assert_eq!(z.state(), ZoneState::Empty);
        assert_eq!(z.wp(), 0);
        assert_eq!(z.reset_count, 1);
        // Space reusable after reset.
        z.append(b"x").unwrap();
        assert_eq!(z.read(0, 1).unwrap(), b"x");
    }

    #[test]
    fn finish_marks_full_and_rejects_appends() {
        let mut z = Zone::new(16);
        z.append(b"abc").unwrap();
        z.finish();
        assert_eq!(z.state(), ZoneState::Full);
        assert!(z.append(b"d").is_err());
        // Reads of written data still work on a finished zone.
        assert_eq!(z.read(0, 3).unwrap(), b"abc");
    }
}
