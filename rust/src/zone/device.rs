//! A zoned device = an array of zones + the QD1 timing server.
//!
//! Data-path methods (`append`, `read_random`, `read_seq`, `reset`) both
//! move (wire-form) data and charge virtual service time, returning the
//! access `(start, finish)` window so callers can thread completion times
//! through the DES. All service times are charged on *logical* lengths.

use crate::config::DeviceProfile;
use crate::residency::{Residency, ResidencyHandle};
use crate::sim::{AccessKind, Ns, SharedTimer};
use crate::trace::{Event, TraceSink};
use crate::wire::WireBuf;

use super::{Dev, Zone, ZoneError, ZoneId, ZoneState};

#[derive(Clone, Copy, Debug, Default)]
pub struct ZoneStats {
    pub empty: u32,
    pub open: u32,
    pub full: u32,
    pub total_resets: u64,
}

/// One zoned storage device (ZNS SSD or HM-SMR HDD profile).
pub struct ZonedDevice {
    pub dev: Dev,
    pub zone_cap: u64,
    zones: Vec<Zone>,
    /// FIFO timing server. A handle, not an inline value: the shard layer
    /// rebinds all shards' devices to one shared server per physical
    /// device (see [`ZonedDevice::set_timer`]).
    pub timer: SharedTimer,
    /// Observation-only trace sink for zone append/reset events (disabled
    /// by default). Untimed paths stamp the sink's last-seen virtual time.
    trace: TraceSink,
    /// Demand-paged residency manager: every byte entering a zone passes
    /// through `page_out` (cold data dehydrates at rest), every byte
    /// leaving through `page_in` (the hydrated read copy is the caller's
    /// pin). A handle like the timer: the shard layer rebinds all shards'
    /// devices to one per-domain manager (see
    /// [`ZonedDevice::set_residency`]).
    residency: ResidencyHandle,
}

impl ZonedDevice {
    pub fn new(dev: Dev, zone_cap: u64, num_zones: u32, profile: DeviceProfile) -> Self {
        ZonedDevice {
            dev,
            zone_cap,
            zones: (0..num_zones).map(|_| Zone::new(zone_cap)).collect(),
            timer: SharedTimer::new(profile),
            trace: TraceSink::disabled(),
            residency: Residency::new(true),
        }
    }

    /// Rebind this device's FIFO timing server. The shard layer points all
    /// shards' SSDs (and HDDs) at one shared server each, so cross-shard
    /// device queueing is modeled; must be called before any access is
    /// charged.
    pub fn set_timer(&mut self, timer: SharedTimer) {
        self.timer = timer;
    }

    /// Rebind the residency manager (per-domain sharing, like
    /// [`ZonedDevice::set_timer`]). Safe at any time: paging never changes
    /// logical contents, and reads always rehydrate data that dehydrated
    /// under a previous manager.
    pub fn set_residency(&mut self, residency: ResidencyHandle) {
        self.residency = residency;
    }

    pub fn residency(&self) -> ResidencyHandle {
        self.residency.clone()
    }

    /// Attach a trace sink (and mirror it onto the timing server, which
    /// emits the `DEV` service intervals). Observation-only.
    pub fn set_trace(&mut self, trace: TraceSink) {
        self.timer.set_trace(trace.clone(), self.dev);
        self.trace = trace;
    }

    pub fn num_zones(&self) -> u32 {
        self.zones.len() as u32
    }

    pub fn zone(&self, id: ZoneId) -> &Zone {
        &self.zones[id as usize]
    }

    /// Find any empty zone.
    pub fn find_empty_zone(&self) -> Option<ZoneId> {
        self.zones.iter().position(|z| z.is_empty()).map(|i| i as ZoneId)
    }

    /// Find `n` empty zones (for HDD-resident SSTs spanning 4 zones).
    pub fn find_empty_zones(&self, n: u32) -> Option<Vec<ZoneId>> {
        let ids: Vec<ZoneId> = self
            .zones
            .iter()
            .enumerate()
            .filter(|(_, z)| z.is_empty())
            .take(n as usize)
            .map(|(i, _)| i as ZoneId)
            .collect();
        (ids.len() == n as usize).then_some(ids)
    }

    pub fn empty_zone_count(&self) -> u32 {
        self.zones.iter().filter(|z| z.is_empty()).count() as u32
    }

    pub fn stats(&self) -> ZoneStats {
        let mut s = ZoneStats::default();
        for z in &self.zones {
            match z.state() {
                ZoneState::Empty => s.empty += 1,
                ZoneState::Open => s.open += 1,
                ZoneState::Full => s.full += 1,
            }
            s.total_resets += z.reset_count;
        }
        s
    }

    /// Append `buf` to `zone` at its write pointer. Returns
    /// `(offset, start, finish)`. Data is paged out on the way in — cold
    /// zone contents dehydrate — without changing logical length, so the
    /// landing offset and the charged service time are paging-invariant.
    pub fn append(
        &mut self,
        now: Ns,
        zone: ZoneId,
        buf: &WireBuf,
    ) -> Result<(u64, Ns, Ns), ZoneError> {
        let staged = self.residency.borrow_mut().page_out(buf);
        let off = self.zones[zone as usize].append_wire(staged.as_ref().unwrap_or(buf))?;
        let (s, f) = self.timer.access(now, AccessKind::SeqWrite, buf.len());
        let (dev, bytes) = (self.dev, buf.len());
        self.trace.emit(|| Event::ZoneAppend { dev, zone, bytes, at: now });
        Ok((off, s, f))
    }

    /// Random (point) read — 4-KiB-block cost model. The returned buffer
    /// is paged in (fully hydrated): it is the caller's pin.
    pub fn read_random(
        &mut self,
        now: Ns,
        zone: ZoneId,
        offset: u64,
        len: u64,
    ) -> Result<(WireBuf, Ns, Ns), ZoneError> {
        let mut data = self.zones[zone as usize].read(offset, len)?;
        self.residency.borrow_mut().page_in(&mut data);
        let (s, f) = self.timer.access(now, AccessKind::RandRead, len);
        Ok((data, s, f))
    }

    /// Sequential (streaming) read — bandwidth cost model. Paged in like
    /// [`ZonedDevice::read_random`].
    pub fn read_seq(
        &mut self,
        now: Ns,
        zone: ZoneId,
        offset: u64,
        len: u64,
    ) -> Result<(WireBuf, Ns, Ns), ZoneError> {
        let mut data = self.zones[zone as usize].read(offset, len)?;
        self.residency.borrow_mut().page_in(&mut data);
        let (s, f) = self.timer.access(now, AccessKind::SeqRead, len);
        Ok((data, s, f))
    }

    /// Charge time for an access without moving bytes (used by chunked
    /// background jobs that account I/O separately from data movement).
    pub fn charge(&mut self, now: Ns, kind: AccessKind, bytes: u64) -> (Ns, Ns) {
        self.timer.access(now, kind, bytes)
    }

    /// Charge ONE fused device access carrying `members` logical requests
    /// (group commit / read coalescing): one `per_req_overhead_ns` for the
    /// whole batch.
    pub fn charge_fused(
        &mut self,
        now: Ns,
        kind: AccessKind,
        bytes: u64,
        members: u32,
    ) -> (Ns, Ns) {
        self.timer.access_fused(now, kind, bytes, members)
    }

    /// Append without charging time (the caller charges chunked I/O
    /// itself). Paged out like [`ZonedDevice::append`].
    pub fn append_untimed(&mut self, zone: ZoneId, buf: &WireBuf) -> Result<u64, ZoneError> {
        let staged = self.residency.borrow_mut().page_out(buf);
        let off = self.zones[zone as usize].append_wire(staged.as_ref().unwrap_or(buf))?;
        let (dev, bytes, at) = (self.dev, buf.len(), self.trace.now_hint());
        self.trace.emit(|| Event::ZoneAppend { dev, zone, bytes, at });
        Ok(off)
    }

    /// Read without charging time. Paged in like
    /// [`ZonedDevice::read_random`].
    pub fn read_untimed(
        &mut self,
        zone: ZoneId,
        offset: u64,
        len: u64,
    ) -> Result<WireBuf, ZoneError> {
        let mut data = self.zones[zone as usize].read(offset, len)?;
        self.residency.borrow_mut().page_in(&mut data);
        Ok(data)
    }

    /// Power-loss truncation of one zone (crash injection): the write
    /// pointer lands at `at`, possibly mid-record. Emits a `ZTRUNC` trace
    /// event carrying the surviving write pointer.
    pub fn power_loss_truncate(&mut self, zone: ZoneId, at: u64) -> u64 {
        let wp = self.zones[zone as usize].power_loss_truncate(at);
        let (dev, at) = (self.dev, self.trace.now_hint());
        self.trace.emit(|| Event::ZoneTrunc { dev, zone, wp, at });
        wp
    }

    /// Reset a zone (instantaneous in the model, as on real devices the
    /// reset cost is negligible next to the data traffic).
    pub fn reset(&mut self, zone: ZoneId) {
        self.zones[zone as usize].reset();
        let (dev, at) = (self.dev, self.trace.now_hint());
        self.trace.emit(|| Event::ZoneReset { dev, zone, at });
    }

    pub fn finish_zone(&mut self, zone: ZoneId) {
        self.zones[zone as usize].finish();
    }

    /// Bytes of live (written) data summed over all zones — *logical*
    /// bytes, as a byte-backed device would report.
    pub fn written_bytes(&self) -> u64 {
        self.zones.iter().map(|z| z.wp()).sum()
    }

    /// Physically resident bytes across all zones (the O(entries) RAM
    /// footprint the zero-materialization data path is pinned on).
    pub fn phys_bytes(&self) -> u64 {
        self.zones.iter().map(|z| z.phys_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MIB;

    fn ssd() -> ZonedDevice {
        ZonedDevice::new(Dev::Ssd, 4 * MIB, 8, DeviceProfile::zn540_ssd())
    }

    fn wire(bytes: &[u8]) -> WireBuf {
        WireBuf::from_bytes(bytes)
    }

    #[test]
    fn allocate_append_read_roundtrip() {
        let mut d = ssd();
        let z = d.find_empty_zone().unwrap();
        let (off, _, f1) = d.append(0, z, &wire(b"zoned-data")).unwrap();
        assert_eq!(off, 0);
        let (data, s2, _) = d.read_random(0, z, 0, 10).unwrap();
        assert_eq!(data.phys_bytes(), b"zoned-data");
        // Second access queued behind the first (QD1).
        assert_eq!(s2, f1);
    }

    #[test]
    fn empty_zone_accounting() {
        let mut d = ssd();
        assert_eq!(d.empty_zone_count(), 8);
        let z = d.find_empty_zone().unwrap();
        d.append(0, z, &wire(&[0u8; 100])).unwrap();
        assert_eq!(d.empty_zone_count(), 7);
        d.reset(z);
        assert_eq!(d.empty_zone_count(), 8);
    }

    #[test]
    fn find_multiple_empty_zones() {
        let mut d = ssd();
        let ids = d.find_empty_zones(4).unwrap();
        assert_eq!(ids.len(), 4);
        for id in &ids {
            d.append(0, *id, &wire(&[1u8; 8])).unwrap();
        }
        assert!(d.find_empty_zones(5).is_none() || d.empty_zone_count() >= 5);
        assert_eq!(d.empty_zone_count(), 4);
    }

    #[test]
    fn sequential_write_discipline_enforced() {
        let mut d = ssd();
        let z = d.find_empty_zone().unwrap();
        d.append(0, z, &wire(&[0u8; 4096])).unwrap();
        // Reading past wp fails.
        assert!(d.read_random(0, z, 4000, 200).is_err());
    }

    #[test]
    fn written_bytes_tracks_wp() {
        let mut d = ssd();
        let z0 = 0;
        let z1 = 1;
        d.append(0, z0, &wire(&[0u8; 100])).unwrap();
        d.append(0, z1, &wire(&[0u8; 50])).unwrap();
        assert_eq!(d.written_bytes(), 150);
    }

    #[test]
    fn appends_dehydrate_at_rest_and_reads_pin_hydrated_copies() {
        let mut d = ssd();
        let mut rec = WireBuf::new();
        for i in 0..8u64 {
            rec.push_entry(
                &crate::ycsb::key_for(i, 24),
                i,
                Some(crate::wire::Payload::fill(1, 200)),
            );
        }
        let z = d.find_empty_zone().unwrap();
        let (off, _, _) = d.append(0, z, &rec).unwrap();
        assert_eq!(off, 0);
        // At rest: heads elided, write pointer still logical.
        assert_eq!(d.zone(z).wp(), rec.len());
        assert_eq!(d.phys_bytes(), 0, "all-YCSB records dehydrate completely");
        assert!(!d.zone(z).is_empty());
        // A read returns the bit-identical hydrated pin; media unchanged.
        let (back, _, _) = d.read_random(0, z, 0, rec.len()).unwrap();
        assert_eq!(back, rec);
        assert_eq!(d.phys_bytes(), 0, "reading must not rehydrate the media");
        let stats = d.residency().borrow().stats;
        assert_eq!(stats.dehydrated_runs, 8);
        assert_eq!(stats.rehydrated_runs, 8);
        // With paging off nothing dehydrates.
        let mut d2 = ssd();
        d2.set_residency(crate::residency::Residency::new(false));
        d2.append(0, 0, &rec).unwrap();
        assert_eq!(d2.phys_bytes(), rec.phys_len() as u64);
        let (back2, _, _) = d2.read_random(0, 0, 0, rec.len()).unwrap();
        assert_eq!(back2, rec);
    }
}
