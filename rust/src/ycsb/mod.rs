//! YCSB workload generator (§4.1): the load phase plus core workloads A–F
//! and the parameterized read/write mixes used by Exp#2–Exp#5.
//!
//! Keys follow YCSB's scrambled scheme: item ranks drawn from a Zipf(α)
//! distribution are FNV-hashed onto the key space, so popularity is
//! scattered across SSTs — the effect behind the paper's "hot SSTs on the
//! HDD" observation (O4). Keys are `user` + 20 hashed digits = 24 bytes;
//! values are synthetic `value_size`-byte fill payloads (deterministic
//! per item), carried as [`Payload`]s so generation costs O(1) per op.

use crate::coordinator::{Op, OpSource};
use crate::sim::rng::{fnv1a_u64, Rng};
use crate::sim::zipf::{KeyChooser, Latest, Uniform, Zipf};
use crate::wire::Payload;

/// Which workload to generate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Kind {
    /// Insert all `records` keys (the load phase).
    Load,
    /// 50% reads / 50% updates, Zipf.
    A,
    /// 95% reads / 5% updates, Zipf.
    B,
    /// 100% reads, Zipf.
    C,
    /// 95% latest-reads / 5% inserts.
    D,
    /// 95% scans / 5% inserts; scan length uniform 1–100.
    E,
    /// 50% reads / 50% read-modify-writes, Zipf.
    F,
    /// `read_pct`% reads, rest updates, Zipf (Exp#2–Exp#5 mixes).
    Mixed { read_pct: u32 },
}

impl Kind {
    pub fn label(&self) -> String {
        match self {
            Kind::Load => "load".into(),
            Kind::A => "A".into(),
            Kind::B => "B".into(),
            Kind::C => "C".into(),
            Kind::D => "D".into(),
            Kind::E => "E".into(),
            Kind::F => "F".into(),
            Kind::Mixed { read_pct } => format!("r{read_pct}"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct Spec {
    pub kind: Kind,
    /// Number of records loaded before the workload runs.
    pub records: u64,
    /// Total operations across all clients.
    pub ops: u64,
    pub alpha: f64,
    pub key_size: usize,
    pub value_size: usize,
    pub seed: u64,
}

impl Spec {
    pub fn from_config(cfg: &crate::config::Config, kind: Kind) -> Self {
        Spec {
            kind,
            records: cfg.workload.load_objects,
            ops: if kind == Kind::Load { cfg.workload.load_objects } else { cfg.workload.ops },
            alpha: cfg.workload.zipf_alpha,
            key_size: cfg.workload.key_size,
            value_size: cfg.workload.value_size,
            seed: cfg.workload.seed,
        }
    }
}

/// Maximum generated key length: `"user"` + up to 124 decimal digits
/// (zero-padded — the key-length sweeps of the bench/gates run at 24, 64,
/// and 128). A ≥ 21-digit field zero-pads on the left, so longer keys
/// share long prefixes exactly like YCSB's fixed-width hashed keys.
pub const MAX_KEY_LEN: usize = 128;

/// Render the low `out.len()` decimal digits of `h` — i.e.
/// `h mod 10^out.len()`, zero-padded, most-significant digit first.
/// This is the fixed-width rendering every generated key field uses,
/// and the one [`parse_user_key`] inverts; `wire`-level key elision
/// re-renders dehydrated keys through it bit-identically.
#[inline]
pub fn render_key_digits(mut h: u64, out: &mut [u8]) {
    for slot in out.iter_mut().rev() {
        *slot = b'0' + (h % 10) as u8;
        h /= 10;
    }
}

/// Write the deterministic key for item `i` into a caller-provided stack
/// buffer (no heap allocation — the hot-path form). Returns the key
/// length `key_size.clamp(8, MAX_KEY_LEN)`. The digit field at
/// `buf[4..n]` carries `fnv1a(i) mod 10^(n-4)`: for `key_size >= 24`
/// (width ≥ 20 decimal digits) that is the full item hash zero-padded —
/// byte-identical to the seed's `format!("user{:020}", hash)` layout —
/// and for narrower keys it is a well-defined modular projection that
/// still parses back to exactly the rendered value. (The seed generator
/// instead kept the HIGH digits of a 20-digit field for `key_size < 24`,
/// silently discarding the information needed to recover the field value
/// from the key bytes; no default or swept configuration used those
/// widths.)
#[inline]
pub fn key_into(i: u64, key_size: usize, buf: &mut [u8; MAX_KEY_LEN]) -> usize {
    let n = key_size.clamp(8, MAX_KEY_LEN);
    buf[..4].copy_from_slice(b"user");
    render_key_digits(fnv1a_u64(i), &mut buf[4..n]);
    n
}

/// Parse a generated YCSB key back to its digit-field value: `"user"`
/// followed by an all-decimal field whose value fits `u64`. Returns the
/// value only when re-rendering it at the same width
/// ([`render_key_digits`]) reproduces the key byte-for-byte — leading
/// zeros included — so `key == render(parse(key))` holds exactly; that
/// bijection is what lets the wire layer elide key bytes and rebuild
/// them on demand. Non-YCSB keys, non-digit bytes, and fields whose
/// value overflows `u64` return `None` (such keys simply stay
/// physically resident).
pub fn parse_user_key(key: &[u8]) -> Option<u64> {
    let digits = key.strip_prefix(b"user")?;
    if digits.is_empty() || digits.len() > MAX_KEY_LEN - 4 {
        return None;
    }
    let mut v: u64 = 0;
    for &b in digits {
        if !b.is_ascii_digit() {
            return None;
        }
        v = v.checked_mul(10)?.checked_add((b - b'0') as u64)?;
    }
    let mut buf = [0u8; MAX_KEY_LEN];
    render_key_digits(v, &mut buf[..digits.len()]);
    if &buf[..digits.len()] == digits {
        Some(v)
    } else {
        None
    }
}

/// Deterministic 24-byte key for item `i` (hashed digits — YCSB order
/// scrambling, so loads insert in key-random order).
pub fn key_for(i: u64, key_size: usize) -> Vec<u8> {
    let mut buf = [0u8; MAX_KEY_LEN];
    let n = key_into(i, key_size, &mut buf);
    buf[..n].to_vec()
}

/// Deterministic value payload for item `i`: the synthetic form of the
/// seed generator's `vec![b; value_size]` fill bytes.
pub fn value_for(i: u64, value_size: usize) -> Payload {
    let b = (fnv1a_u64(i ^ 0xA1B2_C3D4) % 251) as u8;
    Payload::fill(b, value_size)
}

enum Chooser {
    Zipf(Zipf),
    Latest(Latest),
    Uniform(Uniform),
}

impl Chooser {
    fn next(&mut self, rng: &mut Rng) -> u64 {
        match self {
            Chooser::Zipf(z) => z.next(rng),
            Chooser::Latest(l) => l.next(rng),
            Chooser::Uniform(u) => u.next(rng),
        }
    }
    fn grow(&mut self, n: u64) {
        match self {
            Chooser::Latest(l) => l.grow(n),
            Chooser::Zipf(z) => z.grow(n),
            Chooser::Uniform(_) => {}
        }
    }
}

/// The YCSB [`OpSource`]: deterministic per-client streams sharing one key
/// population.
pub struct YcsbSource {
    spec: Spec,
    rngs: Vec<Rng>,
    remaining: Vec<u64>,
    chooser: Chooser,
    /// Current key population (grows under D/E inserts; load counter).
    n_keys: u64,
    next_insert: u64,
    pub ops_emitted: u64,
}

impl YcsbSource {
    pub fn new(spec: Spec, clients: usize) -> Self {
        assert!(clients > 0);
        let mut root = Rng::new(spec.seed ^ 0x9c5b);
        let rngs = (0..clients).map(|c| root.fork(c as u64)).collect();
        let per = spec.ops / clients as u64;
        let mut remaining: Vec<u64> = vec![per; clients];
        remaining[0] += spec.ops - per * clients as u64;
        let records = spec.records.max(1);
        let chooser = match spec.kind {
            Kind::Load => Chooser::Uniform(Uniform::new(records)),
            Kind::D => Chooser::Latest(Latest::new(records, spec.alpha.max(0.01))),
            _ => Chooser::Zipf(Zipf::new(records, clamp_alpha(spec.alpha))),
        };
        YcsbSource {
            n_keys: records,
            next_insert: match spec.kind {
                Kind::Load => 0,
                _ => records,
            },
            spec,
            rngs,
            remaining,
            chooser,
            ops_emitted: 0,
        }
    }

    /// Scrambled-Zipf key choice: rank → hash → existing item index.
    ///
    /// Key bytes are rendered into a stack buffer (`key_into`); the single
    /// remaining allocation is the `Vec` the [`Op`] must own — the seed's
    /// `format!` + `String` + truncate machinery is gone.
    fn choose_key(&mut self, c: usize) -> Vec<u8> {
        let rank = self.chooser.next(&mut self.rngs[c]);
        let idx = match self.spec.kind {
            Kind::D => rank, // latest: ranks ARE recency-ordered indices
            _ => fnv1a_u64(rank) % self.n_keys,
        };
        let mut buf = [0u8; MAX_KEY_LEN];
        let n = key_into(idx, self.spec.key_size, &mut buf);
        buf[..n].to_vec()
    }

    fn insert_new(&mut self) -> Op {
        let i = self.next_insert;
        self.next_insert += 1;
        self.n_keys = self.n_keys.max(self.next_insert);
        self.chooser.grow(self.n_keys);
        Op::Insert {
            key: key_for(i, self.spec.key_size),
            value: value_for(i, self.spec.value_size),
        }
    }
}

fn clamp_alpha(a: f64) -> f64 {
    // The Gray zeta formulation is singular at exactly 1.0.
    if (a - 1.0).abs() < 1e-6 {
        1.000001
    } else {
        a
    }
}

impl OpSource for YcsbSource {
    fn next_op(&mut self, client: usize) -> Option<Op> {
        if self.remaining[client] == 0 {
            return None;
        }
        self.remaining[client] -= 1;
        self.ops_emitted += 1;
        let roll = (self.rngs[client].next_f64() * 100.0) as u32;
        let op = match self.spec.kind {
            Kind::Load => self.insert_new(),
            Kind::A | Kind::Mixed { read_pct: 50 } => {
                if roll < 50 {
                    Op::Read { key: self.choose_key(client) }
                } else {
                    let key = self.choose_key(client);
                    Op::Update { key, value: value_for(roll as u64, self.spec.value_size) }
                }
            }
            Kind::B => {
                if roll < 95 {
                    Op::Read { key: self.choose_key(client) }
                } else {
                    let key = self.choose_key(client);
                    Op::Update { key, value: value_for(roll as u64, self.spec.value_size) }
                }
            }
            Kind::C => Op::Read { key: self.choose_key(client) },
            Kind::D => {
                if roll < 95 {
                    Op::Read { key: self.choose_key(client) }
                } else {
                    self.insert_new()
                }
            }
            Kind::E => {
                if roll < 95 {
                    let len = 1 + (self.rngs[client].next_below(100)) as usize;
                    Op::Scan { key: self.choose_key(client), len }
                } else {
                    self.insert_new()
                }
            }
            Kind::F => {
                if roll < 50 {
                    Op::Read { key: self.choose_key(client) }
                } else {
                    let key = self.choose_key(client);
                    Op::ReadModifyWrite {
                        key,
                        value: value_for(roll as u64, self.spec.value_size),
                    }
                }
            }
            Kind::Mixed { read_pct } => {
                if roll < read_pct {
                    Op::Read { key: self.choose_key(client) }
                } else {
                    let key = self.choose_key(client);
                    Op::Update { key, value: value_for(roll as u64, self.spec.value_size) }
                }
            }
        };
        Some(op)
    }
}

/// The shared frontend stream: a transparent, router-carrying view of the
/// global op stream for the [`crate::shard`] subsystem.
///
/// PR 1 ran one closed-loop client set *per shard*, each filtering its own
/// instance of the global generator down to its shard's ops; `RoutedSource`
/// was that filter. The async frontend owns the clients and routes every
/// op to its home shard itself, so the stream it pulls from is simply the
/// global one — source-side filtering would now *drop* ops (the frontend
/// pulls each op exactly once). `RoutedSource` therefore passes the inner
/// stream through untouched; it keeps its constructor shape (router +
/// shard index, bounds-checked) so PR 1 call sites compile unchanged, and
/// because the view is shard-independent every deterministic property of
/// the inner generator — including D/E population growth, which the old
/// per-shard filtering only approximated — now holds exactly.
pub struct RoutedSource<S: OpSource> {
    inner: S,
    router: crate::shard::Router,
    shard: usize,
}

impl<S: OpSource> RoutedSource<S> {
    pub fn new(inner: S, router: crate::shard::Router, shard: usize) -> Self {
        assert!(shard < router.shards(), "shard index outside the router");
        RoutedSource { inner, router, shard }
    }

    /// The router this view was built for (the frontend's routing is the
    /// authority; this is carried for introspection).
    pub fn router(&self) -> crate::shard::Router {
        self.router
    }

    /// The shard index this view was built with (unused by the
    /// pass-through; kept for API compatibility and debugging).
    pub fn shard(&self) -> usize {
        self.shard
    }
}

impl<S: OpSource> OpSource for RoutedSource<S> {
    fn next_op(&mut self, client: usize) -> Option<Op> {
        self.inner.next_op(client)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(kind: Kind) -> Spec {
        Spec {
            kind,
            records: 10_000,
            ops: 1_000,
            alpha: 0.9,
            key_size: 24,
            value_size: 100,
            seed: 7,
        }
    }

    fn drain(src: &mut YcsbSource, clients: usize) -> Vec<Op> {
        let mut out = Vec::new();
        'outer: loop {
            let mut any = false;
            for c in 0..clients {
                match src.next_op(c) {
                    Some(op) => {
                        out.push(op);
                        any = true;
                    }
                    None => {}
                }
                if out.len() > 10_000 {
                    break 'outer;
                }
            }
            if !any {
                break;
            }
        }
        out
    }

    #[test]
    fn keys_are_24_bytes_and_unique() {
        let mut seen = std::collections::HashSet::new();
        for i in 0..10_000u64 {
            let k = key_for(i, 24);
            assert_eq!(k.len(), 24);
            assert!(seen.insert(k), "duplicate key for item {i}");
        }
    }

    #[test]
    fn wider_keys_zero_pad_and_24_matches_seed_layout() {
        // 24-byte keys: "user" + the 20 low decimal digits of the item
        // hash — byte-identical to the pre-sweep generator (the default
        // key_size timeline must not move).
        let k24 = key_for(42, 24);
        assert_eq!(k24.len(), 24);
        assert_eq!(&k24[..4], b"user");
        let digits = format!("{:020}", fnv1a_u64(42));
        assert_eq!(&k24[4..], digits.as_bytes());
        // Wider keys keep the same 20 significant digits behind a long
        // zero-padded (hence heavily prefix-shared) run.
        let k128 = key_for(42, 128);
        assert_eq!(k128.len(), 128);
        assert_eq!(&k128[..4], b"user");
        assert!(k128[4..108].iter().all(|&b| b == b'0'), "left zero-padding");
        assert_eq!(&k128[108..], &k24[4..]);
        // Clamped at both ends.
        assert_eq!(key_for(7, 2).len(), 8);
        assert_eq!(key_for(7, 4096).len(), MAX_KEY_LEN);
        // Sub-24 sizes keep the LOW digits (`hash mod 10^(n-4)`) so the
        // key bytes always parse back to the rendered value; the seed's
        // high-digit truncation discarded exactly the information a
        // parse needs to reproduce the key.
        let k16 = key_for(42, 16);
        assert_eq!(&k16[..4], b"user");
        let low12 = format!("{:012}", fnv1a_u64(42) % 1_000_000_000_000);
        assert_eq!(&k16[4..], low12.as_bytes());
    }

    #[test]
    fn paper_scale_ids_round_trip_without_truncation() {
        // ≥10M ids: generated keys parse back to their exact item hash —
        // no silent digit truncation anywhere in the id range. Every id
        // through 1M, strided coverage through 10M, plus the extremes.
        let mut buf = [0u8; MAX_KEY_LEN];
        let ids = (0..1_000_000u64)
            .chain((1_000_000..10_000_000).step_by(17))
            .chain([10_000_000, u64::MAX / 2, u64::MAX]);
        for i in ids {
            let n = key_into(i, 24, &mut buf);
            assert_eq!(parse_user_key(&buf[..n]), Some(fnv1a_u64(i)), "id {i}");
        }
        // Sampled distinctness across the 10M-id range (a full set would
        // pin 10M keys in RAM — keeping residency bounded is the point).
        let mut seen = std::collections::HashSet::new();
        for i in (0..10_000_000u64).step_by(1009) {
            let n = key_into(i, 24, &mut buf);
            assert!(seen.insert(buf[..n].to_vec()), "duplicate key at id {i}");
        }
    }

    #[test]
    fn parse_user_key_inverts_every_generated_width() {
        // parse → re-render reproduces the key bytes exactly at every
        // width, including narrow (modular) and padded (≥ 21-digit)
        // fields.
        let mut buf = [0u8; MAX_KEY_LEN];
        for i in [0u64, 42, 9_999_999, u64::MAX] {
            for w in [8usize, 12, 16, 24, 64, MAX_KEY_LEN] {
                let n = key_into(i, w, &mut buf);
                let v = parse_user_key(&buf[..n]).expect("generated keys parse");
                let mut back = [0u8; MAX_KEY_LEN];
                back[..4].copy_from_slice(b"user");
                render_key_digits(v, &mut back[4..n]);
                assert_eq!(&back[..n], &buf[..n], "id {i} width {w}");
            }
        }
        // Rejections: wrong prefix, empty/invalid field, u64 overflow.
        assert_eq!(parse_user_key(b"key-0001"), None);
        assert_eq!(parse_user_key(b"user"), None);
        assert_eq!(parse_user_key(b"user12a4"), None);
        assert_eq!(parse_user_key(b"user99999999999999999999"), None);
        assert_eq!(parse_user_key(b"user18446744073709551615"), Some(u64::MAX));
    }

    #[test]
    fn load_emits_exactly_records_inserts() {
        let mut s = spec(Kind::Load);
        s.ops = s.records;
        let mut src = YcsbSource::new(s, 4);
        let ops = drain(&mut src, 4);
        assert_eq!(ops.len(), 10_000);
        assert!(ops.iter().all(|o| matches!(o, Op::Insert { .. })));
        // All loaded keys distinct.
        let keys: std::collections::HashSet<_> = ops
            .iter()
            .map(|o| match o {
                Op::Insert { key, .. } => key.clone(),
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(keys.len(), 10_000);
    }

    #[test]
    fn workload_c_is_all_reads() {
        let mut src = YcsbSource::new(spec(Kind::C), 2);
        let ops = drain(&mut src, 2);
        assert_eq!(ops.len(), 1000);
        assert!(ops.iter().all(|o| matches!(o, Op::Read { .. })));
    }

    #[test]
    fn workload_a_is_half_reads() {
        let mut src = YcsbSource::new(spec(Kind::A), 2);
        let ops = drain(&mut src, 2);
        let reads = ops.iter().filter(|o| matches!(o, Op::Read { .. })).count();
        assert!((400..600).contains(&reads), "reads={reads}");
    }

    #[test]
    fn workload_e_is_mostly_scans() {
        let mut src = YcsbSource::new(spec(Kind::E), 2);
        let ops = drain(&mut src, 2);
        let scans = ops.iter().filter(|o| matches!(o, Op::Scan { .. })).count();
        assert!(scans > 900, "scans={scans}");
        for o in &ops {
            if let Op::Scan { len, .. } = o {
                assert!((1..=100).contains(len));
            }
        }
    }

    #[test]
    fn workload_d_reads_recent_inserts() {
        let mut src = YcsbSource::new(spec(Kind::D), 1);
        let ops = drain(&mut src, 1);
        let inserts = ops.iter().filter(|o| matches!(o, Op::Insert { .. })).count();
        assert!((20..120).contains(&inserts), "inserts={inserts}");
        // Reads target the most recent region of the key population: the
        // majority should hit the top 20% of item indices.
        let mut recent = 0;
        let mut total = 0;
        for o in &ops {
            if let Op::Read { key } = o {
                total += 1;
                // Recover recency only statistically: the key of a recent
                // item equals key_for(i) for some i near n. Compare against
                // the most recent 2000 items (stack-rendered, no allocs).
                let n = src.n_keys;
                let mut buf = [0u8; MAX_KEY_LEN];
                for i in (n.saturating_sub(2000))..n {
                    let klen = key_into(i, 24, &mut buf);
                    if key.as_slice() == &buf[..klen] {
                        recent += 1;
                        break;
                    }
                }
            }
        }
        assert!(recent * 2 > total, "recent={recent} total={total}");
    }

    #[test]
    fn zipf_reads_are_skewed() {
        let mut src = YcsbSource::new(spec(Kind::C), 1);
        let ops = drain(&mut src, 1);
        let mut counts: std::collections::HashMap<Vec<u8>, usize> = Default::default();
        for o in &ops {
            if let Op::Read { key } = o {
                *counts.entry(key.clone()).or_default() += 1;
            }
        }
        let max = counts.values().max().copied().unwrap_or(0);
        assert!(max > 20, "hottest key only read {max} times out of 1000");
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = YcsbSource::new(spec(Kind::A), 3);
        let mut b = YcsbSource::new(spec(Kind::A), 3);
        for c in [0usize, 1, 2, 0, 1] {
            let (x, y) = (a.next_op(c), b.next_op(c));
            match (x, y) {
                (Some(Op::Read { key: k1 }), Some(Op::Read { key: k2 })) => assert_eq!(k1, k2),
                (Some(Op::Update { key: k1, .. }), Some(Op::Update { key: k2, .. })) => {
                    assert_eq!(k1, k2)
                }
                (None, None) => {}
                other => panic!("streams diverged: {other:?}"),
            }
        }
    }

    #[test]
    fn routed_source_is_the_shared_global_stream_at_any_shard_count() {
        // The frontend pulls each op exactly once and routes it itself, so
        // the view must emit the identical global stream no matter which
        // shard index it was built with.
        use crate::shard::Router;
        let clients = 3;
        for n in [1usize, 4] {
            let router = Router::new(n);
            for s in 0..n {
                let mut global = YcsbSource::new(spec(Kind::A), clients);
                let mut view =
                    RoutedSource::new(YcsbSource::new(spec(Kind::A), clients), router, s);
                assert_eq!(view.shard(), s);
                assert_eq!(view.router().shards(), n);
                for c in [0usize, 1, 2, 0, 1, 2, 2, 1, 0] {
                    let (x, y) = (global.next_op(c), view.next_op(c));
                    assert_eq!(format!("{x:?}"), format!("{y:?}"), "shard {s} of {n} diverged");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "shard index outside the router")]
    fn routed_source_rejects_out_of_range_shard() {
        use crate::shard::Router;
        RoutedSource::new(YcsbSource::new(spec(Kind::A), 1), Router::new(2), 2);
    }

    #[test]
    fn ops_split_across_clients() {
        let mut s = spec(Kind::C);
        s.ops = 10;
        let mut src = YcsbSource::new(s, 3);
        let ops = drain(&mut src, 3);
        assert_eq!(ops.len(), 10);
    }
}
