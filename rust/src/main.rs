//! `hhzs` — the launcher.
//!
//! ```text
//! hhzs exp <table1|fig2|exp1..exp7|all> [--profile quick|default|full]
//!          [--config FILE] [--csv DIR] [--objects N] [--ops N]
//!          [--ssd-zones N] [--alpha F] [--seed N]
//!          exp7 also takes --quick: shards {8,64} at 1x/4x keyspace with
//!          the always-on residency-flatness gate (CI smoke)
//! hhzs bench wallclock [--quick] [--out BENCH_2.json] [--gate]
//!                                     # DES wall-clock + memory benchmark;
//!                                     # --gate enforces the always-armed
//!                                     # invariant gates and, with a measured
//!                                     # committed baseline, fails on >30%
//!                                     # sim-ops/wall-sec per-row regression
//! hhzs bench-devices                  # Table 1 microbench only
//! hhzs demo [--n N] [--shards N]
//!           [--cpu-sched fair|work_conserving|fifo|stall_aware]
//!           [--fg-threads N]          # tiny put/get/scan smoke demo;
//!                                     # fair/work_conserving pick the slot
//!                                     # hold-cap policy, fifo/stall_aware
//!                                     # the wake-order policy, and
//!                                     # --fg-threads > 0 charges per-op CPU
//!                                     # against a contended foreground pool
//! hhzs config [--profile P]           # print the effective config TOML
//! hhzs xla-check                      # load + smoke the AOT kernels
//! hhzs trace run [--out FILE] [--shards N] [--profile P] ...
//!                                     # traced load + YCSB A; writes a
//!                                     # Chrome-trace JSON (open in Perfetto)
//!                                     # and self-checks it
//! hhzs trace check <FILE>             # replay a trace export, assert the
//!                                     # DES invariants (exit 1 on violation)
//! hhzs crash grid [--quick]           # deterministic crash/power-loss grid:
//!                                     # CrashPoint x trigger x seed x shards,
//!                                     # 4 recovery invariants per cell
//! hhzs crash run [--crash-point P] [--crash-at N] [--crash-at-ns NS]
//!                [--crash-seed S] [--shards N] [--trace FILE]
//!                                     # one injected crash cell; --trace also
//!                                     # writes the traced export for
//!                                     # `hhzs trace check`
//! ```
//!
//! Any run-like command also takes `--trace FILE`: tracing is switched on
//! and the export written to FILE when the command completes (demo only;
//! `exp`/`bench` drive many runs and would overwrite the file per run).
//!
//! Request-fusion knobs (all default off; see the `[batch]` TOML section):
//! `--group-commit [--commit-window-ns NS] [--commit-batch-max N]` batches
//! cross-shard WAL appends into one fused device request per commit window,
//! and `--read-coalesce [--coalesce-gap-bytes N]` fuses adjacent SST block
//! reads into one charged access.
//!
//! Argument parsing is hand-rolled (no external crates are available in
//! this offline build environment).

use hhzs::exp::{self, ExpOpts, Profile};
use hhzs::runtime::XlaKernels;
use hhzs::Config;

struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

fn parse_args(argv: &[String]) -> Args {
    let mut positional = Vec::new();
    let mut flags = std::collections::HashMap::new();
    let mut it = argv.iter().peekable();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            let val = if it.peek().map_or(false, |v| !v.starts_with("--")) {
                it.next().unwrap().clone()
            } else {
                "true".to_string()
            };
            flags.insert(name.to_string(), val);
        } else {
            positional.push(a.clone());
        }
    }
    Args { positional, flags }
}

fn build_config(args: &Args) -> anyhow::Result<Config> {
    let mut cfg = if let Some(path) = args.flags.get("config") {
        Config::from_toml(path)?
    } else {
        let profile = args
            .flags
            .get("profile")
            .map(|p| {
                Profile::from_str(p)
                    .ok_or_else(|| anyhow::anyhow!("bad --profile {p:?}"))
            })
            .transpose()?
            .unwrap_or(Profile::Default);
        profile.config()
    };
    if let Some(v) = args.flags.get("objects") {
        cfg.workload.load_objects = v.parse()?;
    }
    if let Some(v) = args.flags.get("ops") {
        cfg.workload.ops = v.parse()?;
    }
    if let Some(v) = args.flags.get("ssd-zones") {
        cfg.geometry.ssd_zones = v.parse()?;
    }
    if let Some(v) = args.flags.get("alpha") {
        cfg.workload.zipf_alpha = v.parse()?;
    }
    if let Some(v) = args.flags.get("seed") {
        cfg.workload.seed = v.parse()?;
    }
    if let Some(v) = args.flags.get("clients") {
        cfg.workload.clients = v.parse()?;
    }
    if let Some(v) = args.flags.get("shards") {
        cfg.shards = v.parse::<usize>()?.max(1);
    }
    if let Some(v) = args.flags.get("cpu-sched") {
        // One flag, both policies (mirrors the `cpu_sched` TOML key):
        // fair/work_conserving set the hold-cap policy, fifo/stall_aware
        // the wake-order policy.
        match (hhzs::config::CpuSched::parse(v), hhzs::config::WakePolicy::parse(v)) {
            (Some(cs), _) => cfg.lsm.cpu_sched = cs,
            (None, Some(wp)) => cfg.lsm.wake = wp,
            (None, None) => anyhow::bail!(
                "bad --cpu-sched {v:?} (fair|work_conserving|fifo|stall_aware)"
            ),
        }
    }
    if let Some(v) = args.flags.get("fg-threads") {
        cfg.lsm.fg_threads = v.parse()?;
    }
    // Request fusion (mirrors the `[batch]` TOML section): `--group-commit`
    // batches cross-shard WAL appends into one fused device request per
    // commit window, `--read-coalesce` fuses adjacent SST block reads.
    if args.flags.contains_key("group-commit") {
        cfg.batch.group_commit = true;
    }
    if let Some(v) = args.flags.get("commit-window-ns") {
        cfg.batch.group_commit = true;
        cfg.batch.commit_window_ns = v.parse()?;
    }
    if let Some(v) = args.flags.get("commit-batch-max") {
        cfg.batch.commit_batch_max = v.parse::<usize>()?;
        anyhow::ensure!(cfg.batch.commit_batch_max > 0, "--commit-batch-max must be > 0");
    }
    if args.flags.contains_key("read-coalesce") {
        cfg.batch.read_coalesce = true;
    }
    if let Some(v) = args.flags.get("coalesce-gap-bytes") {
        cfg.batch.read_coalesce = true;
        cfg.batch.coalesce_gap_bytes = v.parse()?;
    }
    if let Some(v) = args.flags.get("trace") {
        cfg.trace.enabled = true;
        cfg.trace.out = v.clone();
    }
    if let Some(v) = args.flags.get("trace-buffer") {
        cfg.trace.buffer_events = v.parse()?;
    }
    // Crash injection: any trigger/point flag arms the injector (point
    // defaults to mid_flush; see `hhzs crash` for the grid harness).
    if let Some(v) = args.flags.get("crash-point") {
        cfg.crash.enabled = true;
        cfg.crash.point = v.clone();
    }
    if let Some(v) = args.flags.get("crash-at") {
        cfg.crash.enabled = true;
        cfg.crash.at_op = v.parse()?;
    }
    if let Some(v) = args.flags.get("crash-at-ns") {
        cfg.crash.enabled = true;
        cfg.crash.at_time_ns = v.parse()?;
    }
    if let Some(v) = args.flags.get("crash-seed") {
        cfg.crash.seed = v.parse()?;
    }
    if let Some(v) = args.flags.get("crash-shard") {
        cfg.crash.shard = v.parse()?;
    }
    Ok(cfg)
}

fn cmd_exp(args: &Args) -> anyhow::Result<()> {
    let mut name = args
        .positional
        .get(1)
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    // `exp exp7 --quick`: the CI smoke shape of the shard sweep (shards
    // {8, 64} at 1x/4x keyspace with the residency-flatness gate).
    if name == "exp7" && args.flags.contains_key("quick") {
        name = "exp7-quick".to_string();
    }
    let cfg = build_config(args)?;
    if cfg.shards > 1 {
        // The paper drivers (table1/fig2/exp1..exp6) reproduce single-engine
        // results and exp7 sweeps its own shard counts; don't let a --shards
        // flag silently measure something else than the user expects.
        eprintln!(
            "note: `exp` ignores shards = {} (exp1..exp6 are single-engine \
             reproductions; exp7 sweeps 1..256). Use `demo --shards N` to \
             drive a sharded engine directly.",
            cfg.shards
        );
    }
    let opts = ExpOpts {
        cfg,
        csv_dir: Some(
            args.flags.get("csv").cloned().unwrap_or_else(|| "results".to_string()),
        ),
    };
    let t0 = std::time::Instant::now();
    exp::run(&name, &opts)?;
    eprintln!("[exp {name} done in {:.1}s wall]", t0.elapsed().as_secs_f64());
    Ok(())
}

fn cmd_bench_wallclock(args: &Args) -> anyhow::Result<()> {
    let quick = args.flags.contains_key("quick");
    let out = args.flags.get("out").cloned().unwrap_or_else(|| "BENCH_2.json".to_string());
    // --gate: read the committed file at --out as the baseline first and
    // fail if sim-ops/wall-sec regressed >30% on any matching row.
    let gate = args.flags.contains_key("gate");
    hhzs::bench::run_wallclock(quick, &out, gate)?;
    Ok(())
}

fn cmd_demo(args: &Args) -> anyhow::Result<()> {
    use hhzs::policy::HhzsPolicy;
    use hhzs::shard::ShardedEngine;
    use hhzs::ycsb::{key_for, value_for};
    let n: u64 = args.flags.get("n").map_or(Ok(50_000), |v| v.parse())?;
    let cfg = build_config(args)?;
    // `--shards 1` (the default) is bit-for-bit the single-engine system.
    let mut db = ShardedEngine::new(&cfg, |c| Box::new(HhzsPolicy::new(c.lsm.num_levels)));
    println!("loading {n} objects over {} shard(s) ...", db.num_shards());
    for i in 0..n {
        db.put_payload(&key_for(i, 24), value_for(i, cfg.workload.value_size));
    }
    db.quiesce();
    let m = db.merged_metrics();
    let ssts: usize = db.engines.iter().map(|e| e.version.total_ssts()).sum();
    let now = db.engines.iter().map(|e| e.now).max().unwrap_or(0);
    println!(
        "virtual time: {} | SSTs: {} | flushes: {} | compactions: {}",
        hhzs::sim::fmt_ns(now),
        ssts,
        m.flushes,
        m.compactions
    );
    let probe = key_for(n / 2, 24);
    let v = db.get(&probe);
    println!("get(mid key) -> {} bytes", v.map_or(0, |p| p.len));
    println!("scan(50) -> {} entries", db.scan(&key_for(0, 24), 50));
    let shard_label = db.num_shards() > 1;
    for (s, e) in db.engines.iter().enumerate() {
        for (lvl, (ssd, all)) in e.ssd_share_by_level().iter().enumerate() {
            if *all > 0 {
                let prefix = if shard_label { format!("shard {s} ") } else { String::new() };
                println!("  {prefix}L{lvl}: {:.1}% on SSD", *ssd as f64 / *all as f64 * 100.0);
            }
        }
    }
    if db.trace_enabled() && !cfg.trace.out.is_empty() {
        db.export_trace(&cfg.trace.out)?;
        println!("trace written to {}", cfg.trace.out);
    }
    Ok(())
}

/// `hhzs trace run`: the §4.1 protocol (fresh load, then YCSB A) with
/// tracing forced on, export written to `--out` (default `trace.json`),
/// then the invariant checker replayed over the fresh export. This is the
/// CI entry point for the traced 4-shard workload.
fn cmd_trace_run(args: &Args) -> anyhow::Result<()> {
    use hhzs::policy::HhzsPolicy;
    use hhzs::shard::ShardedEngine;
    use hhzs::ycsb::{Kind, Spec, YcsbSource};
    use hhzs::zone::Dev;

    let mut cfg = build_config(args)?;
    cfg.trace.enabled = true;
    if let Some(out) = args.flags.get("out") {
        cfg.trace.out = out.clone();
    }
    if cfg.trace.out.is_empty() {
        cfg.trace.out = "trace.json".to_string();
    }
    let out = cfg.trace.out.clone();

    let mut se = ShardedEngine::new(&cfg, |c| Box::new(HhzsPolicy::new(c.lsm.num_levels)));
    let clients = cfg.workload.clients;
    println!(
        "trace run: {} shard(s), {} objects load + {} ops YCSB A, seed {}",
        se.num_shards(),
        cfg.workload.load_objects,
        cfg.workload.ops,
        cfg.workload.seed
    );
    let mut load = YcsbSource::new(Spec::from_config(&cfg, Kind::Load), clients);
    se.run_shared(&mut load, clients, None, false);
    se.flush_all();
    se.rebalance_migration_budgets();
    let mut a = YcsbSource::new(Spec::from_config(&cfg, Kind::A), clients);
    se.run_shared(&mut a, clients, None, false);
    se.quiesce();

    for (s, m) in se.per_shard_metrics().iter().enumerate() {
        println!(
            "  shard {s}: {} ops, {} stalls ({:.2} ms), queue wait ssd {:.2} ms / \
             hdd {:.2} ms, cpu wait {:.2} ms",
            m.ops_done,
            m.stalls,
            m.stall_ns as f64 / 1e6,
            m.queue_wait.get(&Dev::Ssd).copied().unwrap_or(0) as f64 / 1e6,
            m.queue_wait.get(&Dev::Hdd).copied().unwrap_or(0) as f64 / 1e6,
            m.cpu_wait.sum as f64 / 1e6,
        );
    }

    let export = se.export_trace_string();
    std::fs::write(&out, &export)?;
    println!("trace written to {out} ({} bytes)", export.len());
    let report = hhzs::trace::check_export(&export).map_err(anyhow::Error::msg)?;
    println!("trace check: {}", report.summary());
    for v in &report.violations {
        eprintln!("  violation: {v}");
    }
    anyhow::ensure!(report.ok(), "trace check failed on the fresh export");
    Ok(())
}

/// `hhzs trace check <FILE>`: replay an export and assert the DES
/// invariants; exits nonzero when any violation is found.
fn cmd_trace_check(args: &Args) -> anyhow::Result<()> {
    let path = args
        .positional
        .get(2)
        .ok_or_else(|| anyhow::anyhow!("usage: hhzs trace check <trace.json>"))?;
    let report = hhzs::trace::check_file(path).map_err(anyhow::Error::msg)?;
    println!("{path}: {}", report.summary());
    for v in &report.violations {
        eprintln!("  violation: {v}");
    }
    anyhow::ensure!(report.ok(), "{} violation(s) in {path}", report.violations.len());
    Ok(())
}

/// `hhzs crash grid [--quick]`: sweep the deterministic crash &
/// power-loss cell matrix (CrashPoint × trigger × seed × shard count),
/// asserting the four recovery invariants per cell. Exits nonzero on any
/// violation or if any point variant never tore a mid-record zone
/// append. `--quick` is the CI shape (108 cells).
fn cmd_crash_grid(args: &Args) -> anyhow::Result<()> {
    let quick = args.flags.contains_key("quick");
    let t0 = std::time::Instant::now();
    let sum = hhzs::crashtest::run_grid(quick, |line| println!("{line}"));
    println!(
        "crash grid: {} cells, {} fired, {} torn, {} failure(s) in {:.1}s wall",
        sum.cells,
        sum.fired,
        sum.torn,
        sum.failures.len(),
        t0.elapsed().as_secs_f64()
    );
    for f in &sum.failures {
        eprintln!("  FAIL: {f}");
    }
    anyhow::ensure!(sum.passed(), "crash grid failed ({} failure(s))", sum.failures.len());
    Ok(())
}

/// `hhzs crash run`: one injected crash cell (flags pick the point,
/// trigger, seed, and shard count), with the same invariant battery as a
/// grid cell. `--trace FILE` additionally runs it traced and writes the
/// export — CI pipes that through `hhzs trace check` to validate span
/// unwinding across the power loss.
fn cmd_crash_run(args: &Args) -> anyhow::Result<()> {
    use hhzs::crashtest::{run_cell_traced, Cell};
    use hhzs::sim::CrashPoint;

    let cfg = build_config(args)?;
    let point = CrashPoint::parse(&cfg.crash.point).ok_or_else(|| {
        anyhow::anyhow!("bad --crash-point {:?} (see CrashPoint names)", cfg.crash.point)
    })?;
    let cell = Cell {
        point,
        shards: cfg.shards,
        // Default to an op trigger that reliably crosses.
        at_op: if cfg.crash.at_op == 0 && cfg.crash.at_time_ns == 0 {
            100
        } else {
            cfg.crash.at_op
        },
        at_time: cfg.crash.at_time_ns,
        seed: cfg.crash.seed,
        wake: cfg.lsm.wake,
        fg_threads: cfg.lsm.fg_threads,
    };
    let trace_out = args.flags.get("trace").cloned();
    let (r, export) = run_cell_traced(&cell, trace_out.is_some());
    println!(
        "crash run: {} shards={} at_op={} at_time={} seed={} -> fired={} torn={:?} ops={}",
        cell.point.name(),
        cell.shards,
        cell.at_op,
        cell.at_time,
        cell.seed,
        r.fired,
        r.torn,
        r.ops_issued
    );
    for v in &r.violations {
        eprintln!("  violation: {v}");
    }
    if let (Some(path), Some(export)) = (trace_out, export) {
        std::fs::write(&path, &export)?;
        println!("trace written to {path} ({} bytes)", export.len());
    }
    anyhow::ensure!(r.violations.is_empty(), "{} invariant violation(s)", r.violations.len());
    Ok(())
}

fn cmd_xla_check() -> anyhow::Result<()> {
    if !XlaKernels::artifacts_present("artifacts") {
        anyhow::bail!("artifacts/ missing — run `make artifacts` first");
    }
    let k = XlaKernels::load("artifacts")?;
    println!("PJRT platform: {}", k.platform());
    let fps: Vec<u32> = (0..64).map(|i| i * 2654435761u32).collect();
    let bloom = hhzs::lsm::Bloom::build(&fps, 10);
    let hits = k.bloom_probe(&fps, bloom.words(), bloom.nbits(), bloom.k())?;
    anyhow::ensure!(hits.iter().all(|&h| h), "bloom self-probe failed");
    let scores = k.priority_scores(&[0, 3], &[10.0, 10.0], &[1.0, 1.0])?;
    anyhow::ensure!(scores[0] > scores[1], "priority ordering failed");
    println!("bloom_probe + priority kernels OK (AOT artifacts executable from rust)");
    Ok(())
}

fn usage() -> ! {
    eprintln!(
        "usage: hhzs <exp|bench|bench-devices|demo|config|xla-check|trace|crash> [flags]\n\
         run `hhzs exp all --profile quick` for a fast full sweep\n\
         run `hhzs bench wallclock --quick` for the BENCH_2 wall-clock bench\n\
         run `hhzs trace run --profile quick --shards 4 --out trace.json` for a\n\
         traced workload (Perfetto-loadable JSON), `hhzs trace check FILE` to\n\
         replay its DES invariants, and add `--trace FILE` to `demo` to trace it\n\
         (add `--cpu-sched stall_aware` / `--fg-threads N` to any run-like\n\
         command for stall-aware CPU wakes / contended foreground CPU;\n\
         `--group-commit` / `--read-coalesce` for cross-shard WAL group\n\
         commit and fused SST reads)\n\
         run `hhzs crash grid --quick` for the crash/power-loss injection grid\n\
         (CrashPoint x trigger x seed x shards; asserts the 4 recovery\n\
         invariants per cell) and `hhzs crash run --crash-point mid_flush\n\
         --crash-at 100 --crash-seed 1 --shards 4 [--trace FILE]` for one cell"
    );
    std::process::exit(2);
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = parse_args(&argv);
    match args.positional.first().map(|s| s.as_str()) {
        Some("exp") => cmd_exp(&args),
        Some("bench") => match args.positional.get(1).map(|s| s.as_str()) {
            Some("wallclock") | None => cmd_bench_wallclock(&args),
            Some("devices") => {
                hhzs::exp::table1::run(None);
                Ok(())
            }
            _ => usage(),
        },
        Some("bench-devices") => {
            hhzs::exp::table1::run(None);
            Ok(())
        }
        Some("demo") => cmd_demo(&args),
        Some("config") => {
            let cfg = build_config(&args)?;
            println!("{}", cfg.to_toml());
            Ok(())
        }
        Some("xla-check") => cmd_xla_check(),
        Some("trace") => match args.positional.get(1).map(|s| s.as_str()) {
            Some("run") => cmd_trace_run(&args),
            Some("check") => cmd_trace_check(&args),
            _ => usage(),
        },
        Some("crash") => match args.positional.get(1).map(|s| s.as_str()) {
            Some("grid") => cmd_crash_grid(&args),
            Some("run") => cmd_crash_run(&args),
            _ => usage(),
        },
        _ => usage(),
    }
}
