//! Zone-aware file layer over the two zoned devices — the reproduction of
//! the (modified) ZenFS role in the paper (§3.6): it maps immutable SST
//! files onto dedicated zones, supports *both* devices at once, and keeps
//! the SST → zone mapping in an ordered map (as the original does with
//! `std::map`).
//!
//! Placement policy stays **outside** this layer: callers decide the target
//! device (that is HHZS's job); zenfs only enforces zone mechanics:
//! * an SSD-resident SST occupies exactly one SSD zone (§3.2);
//! * an HDD-resident SST spans `ceil(size / hdd_zone_cap)` dedicated zones;
//! * deleting a file resets its zones (space reclaim = zone reset, §4.1).
//!
//! Some SSD zones can be reserved (WAL/cache pool, §3.2) — file allocation
//! never touches them.
//!
//! File contents are [`WireBuf`]s: every size, extent, and offset is the
//! *logical* one (bit-identical to byte-backed files), and an HDD file may
//! split a value's synthetic run at a zone boundary — reads re-assemble it
//! losslessly.

use std::collections::{BTreeMap, HashSet};

use crate::config::DeviceProfile;
use crate::sim::{AccessKind, Ns};
use crate::wire::WireBuf;
use crate::zone::{Dev, ZoneId, ZonedDevice};

pub type FileId = u64;

/// One contiguous piece of a file on a device zone.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Extent {
    pub zone: ZoneId,
    pub offset: u64,
    pub len: u64,
}

#[derive(Clone, Debug)]
pub struct ZoneFile {
    pub id: FileId,
    pub dev: Dev,
    pub size: u64,
    pub extents: Vec<Extent>,
}

impl ZoneFile {
    /// Translate a logical file offset to (zone, zone offset, run length).
    pub fn translate(&self, offset: u64, len: u64) -> Option<(ZoneId, u64, u64)> {
        let mut base = 0u64;
        for e in &self.extents {
            if offset < base + e.len {
                let within = offset - base;
                let run = (e.len - within).min(len);
                return Some((e.zone, e.offset + within, run));
            }
            base += e.len;
        }
        None
    }
}

/// File-layer errors.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FsError {
    NoSpace(Dev),
    NoSuchFile(FileId),
    Zone(String),
}

impl std::fmt::Display for FsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FsError::NoSpace(d) => write!(f, "no empty zones on {}", d.name()),
            FsError::NoSuchFile(id) => write!(f, "no such file {id}"),
            FsError::Zone(e) => write!(f, "zone error: {e}"),
        }
    }
}

impl std::error::Error for FsError {}

/// The hybrid zoned file system.
pub struct ZenFs {
    pub ssd: ZonedDevice,
    pub hdd: ZonedDevice,
    files: BTreeMap<FileId, ZoneFile>,
    /// SSD zones excluded from file allocation (WAL/cache pool).
    reserved_ssd: HashSet<ZoneId>,
}

impl ZenFs {
    pub fn new(
        ssd_zone_cap: u64,
        ssd_zones: u32,
        hdd_zone_cap: u64,
        hdd_zones: u32,
        ssd_profile: DeviceProfile,
        hdd_profile: DeviceProfile,
    ) -> Self {
        ZenFs {
            ssd: ZonedDevice::new(Dev::Ssd, ssd_zone_cap, ssd_zones, ssd_profile),
            hdd: ZonedDevice::new(Dev::Hdd, hdd_zone_cap, hdd_zones, hdd_profile),
            files: BTreeMap::new(),
            reserved_ssd: HashSet::new(),
        }
    }

    /// Attach a trace sink to both devices (zone events + `DEV` service
    /// intervals on their shared timing servers). Observation-only.
    pub fn set_trace(&mut self, trace: &crate::trace::TraceSink) {
        self.ssd.set_trace(trace.clone());
        self.hdd.set_trace(trace.clone());
    }

    /// Rebind both devices to one per-domain residency manager (the shard
    /// layer shares a single manager across all shards, like the timers).
    pub fn set_residency(&mut self, residency: &crate::residency::ResidencyHandle) {
        self.ssd.set_residency(residency.clone());
        self.hdd.set_residency(residency.clone());
    }

    pub fn device(&mut self, dev: Dev) -> &mut ZonedDevice {
        match dev {
            Dev::Ssd => &mut self.ssd,
            Dev::Hdd => &mut self.hdd,
        }
    }

    pub fn device_ref(&self, dev: Dev) -> &ZonedDevice {
        match dev {
            Dev::Ssd => &self.ssd,
            Dev::Hdd => &self.hdd,
        }
    }

    /// Reserve SSD zones for the WAL/cache pool; returns the zone ids.
    pub fn reserve_ssd_zones(&mut self, n: u32) -> Vec<ZoneId> {
        let mut out = Vec::new();
        for z in 0..self.ssd.num_zones() {
            if out.len() as u32 == n {
                break;
            }
            if !self.reserved_ssd.contains(&z) && self.ssd.zone(z).is_empty() {
                self.reserved_ssd.insert(z);
                out.push(z);
            }
        }
        out
    }

    pub fn reserved_ssd_zones(&self) -> &HashSet<ZoneId> {
        &self.reserved_ssd
    }

    /// Empty SSD zones available for SST files (excludes the reserved pool).
    pub fn ssd_file_zones_free(&self) -> u32 {
        (0..self.ssd.num_zones())
            .filter(|z| self.ssd.zone(*z).is_empty() && !self.reserved_ssd.contains(z))
            .count() as u32
    }

    /// Total SSD zones usable for SST files.
    pub fn ssd_file_zones_total(&self) -> u32 {
        self.ssd.num_zones() - self.reserved_ssd.len() as u32
    }

    fn find_ssd_file_zone(&self) -> Option<ZoneId> {
        (0..self.ssd.num_zones())
            .find(|z| self.ssd.zone(*z).is_empty() && !self.reserved_ssd.contains(z))
    }

    /// Can a file of `size` bytes be placed on `dev` right now?
    pub fn can_place(&self, dev: Dev, size: u64) -> bool {
        match dev {
            Dev::Ssd => size <= self.ssd.zone_cap && self.find_ssd_file_zone().is_some(),
            Dev::Hdd => {
                let need = size.div_ceil(self.hdd.zone_cap).max(1) as u32;
                self.hdd.empty_zone_count() >= need
            }
        }
    }

    /// Write an immutable file (an SST) in full onto `dev`.
    ///
    /// With `charge_time`, device service time is charged at creation and
    /// the finish time returned; background jobs that charge I/O chunk by
    /// chunk themselves pass `charge_time = false`.
    pub fn create_file(
        &mut self,
        now: Ns,
        id: FileId,
        dev: Dev,
        data: &WireBuf,
        charge_time: bool,
    ) -> Result<(ZoneFile, Ns), FsError> {
        let size = data.len();
        let mut extents = Vec::new();
        let mut finish = now;
        match dev {
            Dev::Ssd => {
                if size > self.ssd.zone_cap {
                    return Err(FsError::NoSpace(Dev::Ssd));
                }
                let z = self.find_ssd_file_zone().ok_or(FsError::NoSpace(Dev::Ssd))?;
                let (off, f) = if charge_time {
                    let (off, _, f) =
                        self.ssd.append(now, z, data).map_err(|e| FsError::Zone(e.to_string()))?;
                    (off, f)
                } else {
                    let off = self
                        .ssd
                        .append_untimed(z, data)
                        .map_err(|e| FsError::Zone(e.to_string()))?;
                    (off, now)
                };
                finish = finish.max(f);
                extents.push(Extent { zone: z, offset: off, len: size });
            }
            Dev::Hdd => {
                let need = size.div_ceil(self.hdd.zone_cap).max(1) as u32;
                let zones = self.hdd.find_empty_zones(need).ok_or(FsError::NoSpace(Dev::Hdd))?;
                // Page out ONCE before slicing: zone-boundary cuts then
                // fall on an already-paged buffer, so a cut through an
                // entry head costs only its materialized fragment instead
                // of leaving the whole chunk resident (a chunk that
                // starts mid-head is opaque to a fresh dehydration scan).
                let staged = self.hdd.residency().borrow_mut().page_out(data);
                let data = staged.as_ref().unwrap_or(data);
                let mut written = 0u64;
                for z in zones {
                    let chunk = (size - written).min(self.hdd.zone_cap);
                    let part = data.slice_to_buf(written, chunk);
                    let (off, f) = if charge_time {
                        let (off, _, f) = self
                            .hdd
                            .append(now, z, &part)
                            .map_err(|e| FsError::Zone(e.to_string()))?;
                        (off, f)
                    } else {
                        let off = self
                            .hdd
                            .append_untimed(z, &part)
                            .map_err(|e| FsError::Zone(e.to_string()))?;
                        (off, now)
                    };
                    finish = finish.max(f);
                    extents.push(Extent { zone: z, offset: off, len: chunk });
                    written += chunk;
                    if written >= size {
                        break;
                    }
                }
            }
        }
        let file = ZoneFile { id, dev, size, extents };
        self.files.insert(id, file.clone());
        Ok((file, finish))
    }

    /// Read `len` bytes at `offset` of file `id` with random-read cost.
    pub fn read_file(
        &mut self,
        now: Ns,
        id: FileId,
        offset: u64,
        len: u64,
    ) -> Result<(WireBuf, Ns, Ns), FsError> {
        let file = self.files.get(&id).ok_or(FsError::NoSuchFile(id))?.clone();
        let mut out = WireBuf::new();
        let mut at = offset;
        let mut remaining = len;
        let mut start = Ns::MAX;
        let mut finish = now;
        while remaining > 0 {
            let (zone, zoff, run) =
                file.translate(at, remaining).ok_or(FsError::NoSuchFile(id))?;
            let dev = self.device(file.dev);
            let (data, s, f) = dev
                .read_random(now, zone, zoff, run)
                .map_err(|e| FsError::Zone(e.to_string()))?;
            out.append_buf(&data);
            start = start.min(s);
            finish = finish.max(f);
            at += run;
            remaining -= run;
        }
        Ok((out, start.min(finish), finish))
    }

    /// Read without charging device time (background jobs charge separately
    /// in chunks to allow interleaving).
    pub fn read_file_untimed(
        &mut self,
        id: FileId,
        offset: u64,
        len: u64,
    ) -> Result<WireBuf, FsError> {
        let file = self.files.get(&id).ok_or(FsError::NoSuchFile(id))?.clone();
        let mut out = WireBuf::new();
        let mut at = offset;
        let mut remaining = len;
        while remaining > 0 {
            let (zone, zoff, run) =
                file.translate(at, remaining).ok_or(FsError::NoSuchFile(id))?;
            let dev = self.device(file.dev);
            let data =
                dev.read_untimed(zone, zoff, run).map_err(|e| FsError::Zone(e.to_string()))?;
            out.append_buf(&data);
            at += run;
            remaining -= run;
        }
        Ok(out)
    }

    /// Delete a file and reset its zones (§4.1: "we reset a zone to reclaim
    /// its space only when the ... SST in the zone is deleted").
    pub fn delete_file(&mut self, id: FileId) -> Result<(), FsError> {
        let file = self.files.remove(&id).ok_or(FsError::NoSuchFile(id))?;
        for e in &file.extents {
            self.device(file.dev).reset(e.zone);
        }
        Ok(())
    }

    pub fn file(&self, id: FileId) -> Option<&ZoneFile> {
        self.files.get(&id)
    }

    pub fn file_dev(&self, id: FileId) -> Option<Dev> {
        self.files.get(&id).map(|f| f.dev)
    }

    pub fn num_files(&self) -> usize {
        self.files.len()
    }

    pub fn files(&self) -> impl Iterator<Item = &ZoneFile> {
        self.files.values()
    }

    /// Total bytes of live files — a shard's storage demand, read by the
    /// cross-shard migration arbiter (§3.4 budget split).
    pub fn total_file_bytes(&self) -> u64 {
        self.files.values().map(|f| f.size).sum()
    }

    /// Physically resident bytes across both devices (O(entries), not
    /// O(payload bytes) — pinned by tests). Zones are the only owner of
    /// at-rest bytes, so this sum never double-counts: the block cache
    /// and in-flight cursors hold their own hydrated *copies*, accounted
    /// separately by the per-domain `Metrics::resident_*_bytes` gauges.
    pub fn phys_bytes(&self) -> u64 {
        self.ssd.phys_bytes() + self.hdd.phys_bytes()
    }

    /// Charge device time for a background chunk (compaction/migration).
    pub fn charge(&mut self, now: Ns, dev: Dev, kind: AccessKind, bytes: u64) -> (Ns, Ns) {
        self.device(dev).charge(now, kind, bytes)
    }

    /// Charge ONE fused access carrying `members` logical requests.
    pub fn charge_fused(
        &mut self,
        now: Ns,
        dev: Dev,
        kind: AccessKind,
        bytes: u64,
        members: u32,
    ) -> (Ns, Ns) {
        self.device(dev).charge_fused(now, kind, bytes, members)
    }

    /// Move a file's bytes to the other device (migration, §3.4). Data is
    /// copied untimed — the migration actor charges rate-limited chunk I/O
    /// itself — and the old zones are reset.
    pub fn relocate_file(&mut self, id: FileId, to: Dev) -> Result<(), FsError> {
        let file = self.files.get(&id).ok_or(FsError::NoSuchFile(id))?.clone();
        if file.dev == to {
            return Ok(());
        }
        if !self.can_place(to, file.size) {
            return Err(FsError::NoSpace(to));
        }
        let data = self.read_file_untimed(id, 0, file.size)?;
        self.delete_file(id)?;
        self.create_file(0, id, to, &data, false)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MIB;
    use crate::wire::Payload;

    fn fs() -> ZenFs {
        ZenFs::new(
            4 * MIB,
            8,
            MIB,
            64,
            DeviceProfile::zn540_ssd(),
            DeviceProfile::st14000_smr_hdd(),
        )
    }

    fn wire(bytes: &[u8]) -> WireBuf {
        WireBuf::from_bytes(bytes)
    }

    #[test]
    fn ssd_file_occupies_one_zone() {
        let mut f = fs();
        let data = wire(&vec![7u8; (3 * MIB) as usize]);
        let (file, _) = f.create_file(0, 1, Dev::Ssd, &data, true).unwrap();
        assert_eq!(file.extents.len(), 1);
        assert_eq!(f.ssd.empty_zone_count(), 7);
        let (back, _, _) = f.read_file(0, 1, MIB, 100).unwrap();
        assert_eq!(back.phys_bytes(), &vec![7u8; 100][..]);
    }

    #[test]
    fn hdd_file_spans_multiple_zones() {
        let mut f = fs();
        let data: Vec<u8> = (0..(3 * MIB + 512)).map(|i| (i % 251) as u8).collect();
        let (file, _) = f.create_file(0, 2, Dev::Hdd, &wire(&data), true).unwrap();
        assert_eq!(file.extents.len(), 4);
        // Cross-extent read comes back intact.
        let off = MIB - 100;
        let (back, _, _) = f.read_file(0, 2, off, 300).unwrap();
        let expect: Vec<u8> = (off..off + 300).map(|i| (i % 251) as u8).collect();
        assert_eq!(back.phys_bytes(), &expect[..]);
    }

    #[test]
    fn hdd_zone_boundary_may_split_a_synthetic_run() {
        // A wire-form file whose value runs straddle the 1-MiB HDD zone
        // boundary must survive the split + reassembly byte-identically.
        let mut f = fs();
        let mut data = WireBuf::new();
        let mut n = 0u64;
        while data.len() < 2 * MIB + 4096 {
            data.push_entry(
                format!("user{n:016}").as_bytes(),
                n,
                Some(Payload::fill((n % 251) as u8, 65_000)),
            );
            n += 1;
        }
        let size = data.len();
        let (file, _) = f.create_file(0, 9, Dev::Hdd, &data, true).unwrap();
        assert!(file.extents.len() >= 3);
        let back = f.read_file_untimed(9, 0, size).unwrap();
        // Reassembly preserves content exactly (a split run comes back as
        // adjacent partial runs, so compare logically, not structurally).
        assert_eq!(back.len(), data.len());
        assert_eq!(back.phys_bytes(), data.phys_bytes());
        let decoded: Vec<_> = back.entries().collect();
        assert_eq!(decoded.len(), n as usize);
        for (i, e) in decoded.iter().enumerate() {
            assert_eq!(e.value, Some(Payload::fill((i as u64 % 251) as u8, 65_000)));
        }
    }

    #[test]
    fn paged_files_dehydrate_at_rest_across_zone_boundaries() {
        // A multi-zone HDD file of YCSB entries dehydrates almost
        // completely at rest — only the entry heads cut by zone
        // boundaries stay resident as materialized fragments — and every
        // read rehydrates bit-identically.
        let mut f = fs();
        let mut data = WireBuf::new();
        let mut n = 0u64;
        while data.len() < 2 * MIB + 4096 {
            data.push_entry(
                &crate::ycsb::key_for(n, 24),
                n,
                Some(Payload::fill((n % 251) as u8, 60_000)),
            );
            n += 1;
        }
        let size = data.len();
        let (file, _) = f.create_file(0, 21, Dev::Hdd, &data, true).unwrap();
        assert!(file.extents.len() >= 3);
        let head = (crate::wire::ENTRY_HEADER + 24) as u64;
        assert!(
            f.phys_bytes() < file.extents.len() as u64 * head,
            "at most one cut head fragment per boundary may stay resident ({} bytes)",
            f.phys_bytes()
        );
        // Reads rehydrate bit-identically (compare logically, not
        // structurally: reassembly leaves value runs split at the zone
        // boundaries, exactly like the un-paged read path).
        let back = f.read_file_untimed(21, 0, size).unwrap();
        assert_eq!(back.len(), data.len());
        assert_eq!(back.phys_bytes(), data.phys_bytes());
        let got: Vec<_> = back.entries().map(|e| (e.key.to_vec(), e.seq, e.value)).collect();
        let want: Vec<_> = data.entries().map(|e| (e.key.to_vec(), e.seq, e.value)).collect();
        assert_eq!(got, want);
        // Point reads at arbitrary offsets (crossing a zone boundary
        // mid-value) hydrate the same bytes as a plain slice.
        let (point, _, _) = f.read_file(0, 21, MIB - 333, 70_000).unwrap();
        let plain = data.slice_to_buf(MIB - 333, 70_000);
        assert_eq!(point.len(), plain.len());
        assert_eq!(point.phys_bytes(), plain.phys_bytes());
    }

    #[test]
    fn delete_resets_zones() {
        let mut f = fs();
        let data = wire(&vec![1u8; (2 * MIB) as usize]);
        f.create_file(0, 3, Dev::Hdd, &data, true).unwrap();
        assert_eq!(f.hdd.empty_zone_count(), 62);
        f.delete_file(3).unwrap();
        assert_eq!(f.hdd.empty_zone_count(), 64);
        assert!(f.file(3).is_none());
    }

    #[test]
    fn reserved_zones_not_used_for_files() {
        let mut f = fs();
        let reserved = f.reserve_ssd_zones(2);
        assert_eq!(reserved.len(), 2);
        assert_eq!(f.ssd_file_zones_total(), 6);
        for i in 0..6 {
            f.create_file(0, 10 + i, Dev::Ssd, &wire(&vec![0u8; MIB as usize]), true).unwrap();
        }
        assert!(!f.can_place(Dev::Ssd, MIB));
        assert_eq!(f.ssd.empty_zone_count(), 2, "reserved zones stay empty");
    }

    #[test]
    fn no_space_error() {
        let mut f = fs();
        for i in 0..8 {
            f.create_file(0, i, Dev::Ssd, &wire(&[0u8; 16]), true).unwrap();
        }
        assert_eq!(
            f.create_file(0, 99, Dev::Ssd, &wire(&[0u8; 16]), true).unwrap_err(),
            FsError::NoSpace(Dev::Ssd)
        );
    }

    #[test]
    fn oversized_ssd_file_rejected() {
        let mut f = fs();
        let too_big = wire(&vec![0u8; (5 * MIB) as usize]);
        assert!(f.create_file(0, 1, Dev::Ssd, &too_big, true).is_err());
    }

    #[test]
    fn relocate_preserves_content() {
        let mut f = fs();
        let data: Vec<u8> = (0..2 * MIB).map(|i| (i % 13) as u8).collect();
        f.create_file(0, 5, Dev::Ssd, &wire(&data), true).unwrap();
        f.relocate_file(5, Dev::Hdd).unwrap();
        assert_eq!(f.file_dev(5), Some(Dev::Hdd));
        let back = f.read_file_untimed(5, MIB, 1000).unwrap();
        assert_eq!(back.phys_bytes(), &data[MIB as usize..MIB as usize + 1000]);
        assert_eq!(f.ssd.empty_zone_count(), 8, "SSD zone reclaimed");
    }

    #[test]
    fn relocate_to_full_device_fails_cleanly() {
        let mut f = fs();
        let data = wire(&[0u8; 100]);
        f.create_file(0, 1, Dev::Hdd, &data, true).unwrap();
        for i in 0..8 {
            f.create_file(0, 10 + i, Dev::Ssd, &wire(&[0u8; 4]), true).unwrap();
        }
        assert_eq!(f.relocate_file(1, Dev::Ssd).unwrap_err(), FsError::NoSpace(Dev::Ssd));
        assert_eq!(f.file_dev(1), Some(Dev::Hdd), "file untouched on failure");
    }

    #[test]
    fn total_file_bytes_tracks_live_files() {
        let mut f = fs();
        assert_eq!(f.total_file_bytes(), 0);
        f.create_file(0, 1, Dev::Ssd, &wire(&[0u8; 1000]), true).unwrap();
        f.create_file(0, 2, Dev::Hdd, &wire(&[0u8; 2000]), true).unwrap();
        assert_eq!(f.total_file_bytes(), 3000);
        f.delete_file(1).unwrap();
        assert_eq!(f.total_file_bytes(), 2000);
    }

    #[test]
    fn timing_charged_on_create() {
        let mut f = fs();
        let data = wire(&vec![0u8; MIB as usize]);
        let (_, finish) = f.create_file(0, 1, Dev::Hdd, &data, true).unwrap();
        // 1 MiB at 210 MiB/s ≈ 4.76 ms (+0.1 ms overhead).
        assert!(finish > 4_000_000 && finish < 6_000_000, "finish={finish}");
        let (_, f2) = f.create_file(0, 2, Dev::Hdd, &data, false).unwrap();
        assert_eq!(f2, 0, "untimed create returns caller time");
    }

    #[test]
    fn translate_cross_extent() {
        let file = ZoneFile {
            id: 1,
            dev: Dev::Hdd,
            size: 200,
            extents: vec![
                Extent { zone: 3, offset: 0, len: 100 },
                Extent { zone: 7, offset: 0, len: 100 },
            ],
        };
        assert_eq!(file.translate(0, 50), Some((3, 0, 50)));
        assert_eq!(file.translate(90, 50), Some((3, 90, 10)));
        assert_eq!(file.translate(100, 50), Some((7, 0, 50)));
        assert_eq!(file.translate(250, 1), None);
    }
}
