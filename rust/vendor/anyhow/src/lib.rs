//! A minimal, dependency-free subset of the `anyhow` API, vendored so the
//! crate builds fully offline (no registry access in this environment).
//!
//! Covers exactly what this workspace uses: [`Error`], [`Result`], the
//! [`anyhow!`]/[`bail!`]/[`ensure!`] macros, and the [`Context`] extension
//! trait for `Result` and `Option`. Error chains are flattened into a
//! single message string (`context: cause`), which is all the callers
//! ever render.

use std::fmt;

/// A flattened error: the message plus (already-joined) context prefixes.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer (`context: cause`).
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: like the real `anyhow`, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes the blanket conversion below
// coherent with `impl<T> From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `Result` with a defaulted error type, as in the real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            // Not routed through format!: the stringified condition may
            // itself contain braces.
            return Err($crate::Error::msg(concat!(
                "condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/at/all")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("12").unwrap(), 12);
        assert!(parse("12x").is_err());
        assert!(io_fail().is_err());
    }

    #[test]
    fn context_layers_render_outermost_first() {
        let e: Result<()> = Err(Error::msg("inner"));
        let e = e.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn ensure_and_bail() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(-1).unwrap_err().to_string().contains("positive"));
        assert!(f(200).unwrap_err().to_string().contains("too big"));
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
    }
}
