//! API-compatible stub of the PJRT/XLA binding (`xla-rs` shape) used by
//! `hhzs`'s off-by-default `xla` cargo feature.
//!
//! This vendor crate exists so `cargo build --features xla` still
//! *compiles* in the offline environment. Every entry point returns a
//! "PJRT runtime unavailable" error at runtime; `hhzs` treats that as
//! "run with the native kernels" (its callers check
//! `XlaKernels::artifacts_present` / `load` before dispatching). To run
//! the real AOT Pallas kernels, replace this directory with the actual
//! binding (see `/opt/xla-example` on the lab image) — the `hhzs` code
//! needs no changes.

use std::fmt;
use std::path::Path;

/// Stub error: every operation fails with this.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} is unavailable — this build links the vendored PJRT stub; \
         install the real xla binding to execute AOT kernels"
    ))
}

/// A host literal (stub: carries no data).
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T>(_v: T) -> Literal {
        Literal
    }

    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        Err(unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable("Literal::to_vec"))
    }
}

/// A device buffer returned by an execution (stub: never constructed).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// An HLO module parsed from text (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled executable (stub: never constructed).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A PJRT client (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(Literal::vec1(&[1u32, 2]).to_vec::<i32>().is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("stub"));
    }
}
