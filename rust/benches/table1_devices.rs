//! `cargo bench` target regenerating the paper's table1 artefact.
//! Full-size run: `HHZS_BENCH_FULL=1 cargo bench --bench table1_devices`.
#[path = "bench_util.rs"]
mod bench_util;

fn main() {
    bench_util::run_experiment("table1");
}
