//! `cargo bench` target regenerating the paper's exp2 artefact.
//! Full-size run: `HHZS_BENCH_FULL=1 cargo bench --bench exp2_breakdown`.
#[path = "bench_util.rs"]
mod bench_util;

fn main() {
    bench_util::run_experiment("exp2");
}
