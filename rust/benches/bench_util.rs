//! Minimal bench harness shared by the `cargo bench` targets (criterion is
//! not available in this offline environment). Each experiment bench runs
//! the corresponding paper table/figure at the Quick profile (or Default
//! with `HHZS_BENCH_FULL=1`) and reports wall time; component benches do
//! classic iterate-and-time micro-measurement with warmup.

#![allow(dead_code)]

use std::time::Instant;

pub fn profile() -> hhzs::exp::Profile {
    if std::env::var("HHZS_BENCH_FULL").is_ok() {
        hhzs::exp::Profile::Default
    } else {
        hhzs::exp::Profile::Quick
    }
}

pub fn opts() -> hhzs::exp::ExpOpts {
    hhzs::exp::ExpOpts { cfg: profile().config(), csv_dir: Some("results".into()) }
}

/// Run one experiment driver and report wall time.
pub fn run_experiment(name: &str) {
    let o = opts();
    println!("\n##### bench: {name} (profile {:?}) #####", profile());
    let t0 = Instant::now();
    hhzs::exp::run(name, &o).expect("experiment runs");
    println!("##### {name}: {:.2}s wall #####", t0.elapsed().as_secs_f64());
}

/// Classic micro-bench: warm up, then time `iters` calls of `f`, reporting
/// ns/iter and throughput.
pub fn bench_fn<F: FnMut()>(name: &str, iters: u64, mut f: F) {
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let dt = t0.elapsed();
    let ns = dt.as_nanos() as f64 / iters as f64;
    println!(
        "{name:<44} {ns:>12.1} ns/iter {:>14.0} iters/s",
        1e9 / ns.max(1e-9)
    );
}
