//! `cargo bench` target regenerating the paper's exp3 artefact.
//! Full-size run: `HHZS_BENCH_FULL=1 cargo bench --bench exp3_skew`.
#[path = "bench_util.rs"]
mod bench_util;

fn main() {
    bench_util::run_experiment("exp3");
}
