//! `cargo bench` target regenerating the paper's exp6 artefact.
//! Full-size run: `HHZS_BENCH_FULL=1 cargo bench --bench exp6_migration`.
#[path = "bench_util.rs"]
mod bench_util;

fn main() {
    bench_util::run_experiment("exp6");
}
