//! Component micro-benchmarks: the building blocks on the request path and
//! inside the DES. These are the §Perf profiling probes recorded in
//! EXPERIMENTS.md — run before/after every hot-path change.

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::bench_fn;
use hhzs::config::Config;
use hhzs::coordinator::Engine;
use hhzs::lsm::sst::{build_sst, search_block};
use hhzs::lsm::{Bloom, Entry, MemTable};
use hhzs::policy::HhzsPolicy;
use hhzs::sim::rng::{fingerprint32, Rng};
use hhzs::sim::zipf::{KeyChooser, Zipf};
use hhzs::ycsb::{key_for, value_for};

fn main() {
    println!("== component benchmarks ==");

    // Bloom filter: build + probe.
    let fps: Vec<u32> = (0..4000u64).map(|i| fingerprint32(&i.to_be_bytes())).collect();
    bench_fn("bloom::build(4000 keys, 10 bpk)", 200, || {
        std::hint::black_box(Bloom::build(&fps, 10));
    });
    let bloom = Bloom::build(&fps, 10);
    let mut i = 0u64;
    bench_fn("bloom::may_contain", 2_000_000, || {
        i = i.wrapping_add(0x9E3779B97F4A7C15);
        std::hint::black_box(bloom.may_contain(i as u32));
    });

    // Zipf sampling.
    let mut z = Zipf::new(1_000_000, 0.9);
    let mut rng = Rng::new(7);
    bench_fn("zipf::next(n=1M, a=0.9)", 2_000_000, || {
        std::hint::black_box(z.next(&mut rng));
    });

    // MemTable insert/get.
    let mut mem = MemTable::new();
    let mut seq = 0u64;
    bench_fn("memtable::insert(1KiB value)", 200_000, || {
        seq += 1;
        mem.insert(key_for(seq % 50_000, 24).into(), seq, Some(value_for(seq, 1000)));
    });
    bench_fn("memtable::get", 500_000, || {
        seq += 1;
        std::hint::black_box(mem.get(&key_for(seq % 50_000, 24)));
    });

    // SST block search.
    let entries: Vec<Entry> = (0..4000u64)
        .map(|i| Entry { key: key_for(i, 24).into(), seq: i, value: Some(value_for(i, 1000)) })
        .collect();
    let mut sorted = entries.clone();
    sorted.sort_by(|a, b| a.key.cmp(&b.key));
    let (meta, data) = build_sst(&sorted, 1, 1, 4096, 10, 0);
    bench_fn("sst::find_block + search_block", 500_000, || {
        seq += 1;
        let key = key_for(seq % 4000, 24);
        if let Some(bi) = meta.find_block(&key) {
            let h = &meta.blocks[bi];
            let block = data.slice_to_buf(h.offset, h.len as u64);
            std::hint::black_box(search_block(&block, &key));
        }
    });

    // End-to-end engine paths (virtual-time ops; wall cost is what the DES
    // spends per op).
    let cfg = Config::tiny();
    let mut e = Engine::new(cfg.clone(), Box::new(HhzsPolicy::new(cfg.lsm.num_levels)));
    for i in 0..60_000u64 {
        e.put_payload(&key_for(i, 24), value_for(i, 1000));
    }
    e.quiesce();
    let mut k = 0u64;
    bench_fn("engine::put (incl. DES)", 50_000, || {
        k += 1;
        e.put_payload(&key_for(k % 60_000, 24), value_for(k, 1000));
    });
    e.quiesce();
    bench_fn("engine::get (incl. DES)", 50_000, || {
        k += 1;
        std::hint::black_box(e.get(&key_for((k * 7) % 60_000, 24)));
    });
    bench_fn("engine::scan(10)", 5_000, || {
        k += 1;
        std::hint::black_box(e.scan(&key_for(k % 60_000, 24), 10));
    });

    // XLA kernels, when the artifacts exist.
    if hhzs::runtime::XlaKernels::artifacts_present("artifacts") {
        let kx = hhzs::runtime::XlaKernels::load("artifacts").unwrap();
        let words = bloom.words().to_vec();
        let probe_fps: Vec<u32> = (0..128u32).collect();
        bench_fn("xla::bloom_probe(128 fps) [PJRT dispatch]", 300, || {
            std::hint::black_box(
                kx.bloom_probe(&probe_fps, &words, bloom.nbits(), bloom.k()).unwrap(),
            );
        });
        let levels = vec![3i32; 256];
        let reads = vec![10f32; 256];
        let ages = vec![1f32; 256];
        bench_fn("xla::priority_scores(256) [PJRT dispatch]", 300, || {
            std::hint::black_box(kx.priority_scores(&levels, &reads, &ages).unwrap());
        });
    } else {
        println!("(skipping XLA component benches: run `make artifacts`)");
    }
}
