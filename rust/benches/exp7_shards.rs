//! `cargo bench` target regenerating the Exp#7 shard-scalability artefact.
//! Full-size run: `HHZS_BENCH_FULL=1 cargo bench --bench exp7_shards`.
#[path = "bench_util.rs"]
mod bench_util;

fn main() {
    bench_util::run_experiment("exp7");
}
