//! `cargo bench` target regenerating the paper's fig2 artefact.
//! Full-size run: `HHZS_BENCH_FULL=1 cargo bench --bench fig2_basics`.
#[path = "bench_util.rs"]
mod bench_util;

fn main() {
    bench_util::run_experiment("fig2");
}
