//! Quickstart: the HHZS public API in five minutes.
//!
//! Builds a hybrid zoned store (simulated ZNS SSD + HM-SMR HDD under a
//! virtual clock), mounts the LSM-tree KV engine with the full HHZS policy,
//! and exercises puts, gets, deletes, overwrites, and scans.
//!
//! Run: `cargo run --release --example quickstart`

use hhzs::config::Config;
use hhzs::coordinator::Engine;
use hhzs::policy::HhzsPolicy;
use hhzs::sim::fmt_ns;

fn main() {
    // A small paper-proportioned geometry: SSD zones ≈ 1 MiB (1/1024 of
    // the ZN540), SST ≈ 4 HDD zones, 20 SSD zones, 2 reserved for WAL+cache.
    let cfg = Config::paper_scaled(1024);
    let mut db = Engine::new(cfg.clone(), Box::new(HhzsPolicy::new(cfg.lsm.num_levels)));

    // --- puts -----------------------------------------------------------
    println!("writing 60,000 KV objects (24 B keys / 1,000 B values)...");
    for i in 0..60_000u64 {
        let key = hhzs::ycsb::key_for(i, 24);
        let value = hhzs::ycsb::value_for(i, 1000);
        db.put_payload(&key, value);
    }
    db.quiesce(); // let background flush/compaction/migration settle

    println!(
        "  virtual time {} | {} SSTs | {} flushes | {} compactions | {} migrations",
        fmt_ns(db.now),
        db.version.total_ssts(),
        db.metrics.flushes,
        db.metrics.compactions,
        db.metrics.migrations_cap + db.metrics.migrations_pop,
    );

    // --- reads ----------------------------------------------------------
    let k = hhzs::ycsb::key_for(31_337, 24);
    let v = db.get(&k).expect("key written above");
    assert_eq!(v, hhzs::ycsb::value_for(31_337, 1000));
    println!("  get(key 31337) -> {} bytes OK", v.len);

    // --- overwrite & delete ---------------------------------------------
    db.put(&k, b"fresh value");
    assert_eq!(db.get(&k), Some(hhzs::wire::Payload::from_bytes(b"fresh value")));
    db.delete(&k);
    assert_eq!(db.get(&k), None);
    println!("  overwrite + delete OK");

    // --- scans ----------------------------------------------------------
    let n = db.scan(&hhzs::ycsb::key_for(0, 24), 100);
    println!("  scan(100) -> {n} entries OK");

    // --- where did the data land? ----------------------------------------
    println!("placement (write-guided, per level):");
    for (lvl, (ssd, all)) in db.ssd_share_by_level().iter().enumerate() {
        if *all > 0 {
            println!(
                "  L{lvl}: {:>11} bytes, {:>5.1}% on SSD",
                all,
                *ssd as f64 / *all as f64 * 100.0
            );
        }
    }
    println!(
        "devices: SSD {:.1}% busy, HDD {:.1}% busy (virtual)",
        db.fs.ssd.timer.utilization(db.now) * 100.0,
        db.fs.hdd.timer.utilization(db.now) * 100.0,
    );
}
