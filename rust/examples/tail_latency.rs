//! Migration-interference demo (the Exp#6 phenomenon): how the §3.4 rate
//! limit trades migration speed against foreground read tail latency.
//!
//! Run: `cargo run --release --example tail_latency`

use hhzs::config::MIB;
use hhzs::exp::common::{load_and_run, Profile};
use hhzs::sim::fmt_ns;
use hhzs::ycsb::Kind;

fn main() {
    let base = Profile::Quick.config();
    println!("P+M under a 50/50 mix at alpha=0.9, sweeping the migration rate limit:");
    println!(
        "{:>10} {:>10} {:>10} {:>10} {:>12} {:>12}",
        "rate", "p99", "p99.9", "p99.99", "migrations", "migr-bytes"
    );
    for rate_mib in [1.0f64, 4.0, 16.0, 64.0] {
        let mut cfg = base.clone();
        cfg.hhzs.migration_rate_bps = rate_mib * MIB as f64;
        let (_, m) = load_and_run(&cfg, "P+M", Kind::Mixed { read_pct: 50 }, 0.9);
        println!(
            "{:>7.0}MiB {:>10} {:>10} {:>10} {:>12} {:>12}",
            rate_mib,
            fmt_ns(m.read_lat.quantile(0.99)),
            fmt_ns(m.read_lat.quantile(0.999)),
            fmt_ns(m.read_lat.quantile(0.9999)),
            m.migrations_cap + m.migrations_pop,
            m.migration_bytes,
        );
    }
    println!("\nExpected shape (paper Fig 10): p99 roughly flat; p99.9/p99.99 grow");
    println!("with the migration rate as bulk chunks queue ahead of point reads.");
}
