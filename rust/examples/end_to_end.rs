//! End-to-end driver: proves all three layers compose on a real workload.
//!
//! * Layer 1/2: the AOT-lowered Pallas kernels (`artifacts/*.hlo.txt`,
//!   built once by `make artifacts`) are loaded through PJRT and invoked
//!   from the Rust request path — batched Bloom probing in `multi_get`
//!   and XLA-scored migration decisions in the HHZS policy.
//! * Layer 3: the full coordinator — load 80 MiB of KV objects over the
//!   simulated hybrid zoned devices, run a skewed YCSB-B-style phase, then
//!   serve batched point reads.
//!
//! The run asserts bit-identical results between the XLA and native read
//! paths and reports throughput/latency — the numbers recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//! Run: `make artifacts && cargo run --release --example end_to_end`

use std::rc::Rc;

use hhzs::coordinator::Engine;
use hhzs::exp::common::Profile;
use hhzs::policy::HhzsPolicy;
use hhzs::runtime::XlaKernels;
use hhzs::sim::fmt_ns;
use hhzs::ycsb::{Kind, Spec, YcsbSource};

fn main() -> anyhow::Result<()> {
    // ---- Layer 1/2: load the AOT artifacts -----------------------------
    if !XlaKernels::artifacts_present("artifacts") {
        anyhow::bail!("artifacts/ missing — run `make artifacts` first");
    }
    let kernels = Rc::new(XlaKernels::load("artifacts")?);
    println!("[L1/L2] PJRT platform: {} — bloom_probe + priority kernels loaded", kernels.platform());

    // ---- Layer 3: build the coordinator with XLA attached ---------------
    let cfg = Profile::Quick.config();
    let policy = HhzsPolicy::new(cfg.lsm.num_levels).with_scorer(kernels.clone());
    let mut db = Engine::new(cfg.clone(), Box::new(policy));
    db.attach_xla(kernels.clone());

    // ---- load phase ------------------------------------------------------
    let t0 = std::time::Instant::now();
    let spec = Spec::from_config(&cfg, Kind::Load);
    let mut src = YcsbSource::new(spec, cfg.workload.clients);
    db.run(&mut src, cfg.workload.clients, None, false);
    let load = std::mem::take(&mut db.metrics);
    println!(
        "[load ] {} objects at {:.0} ops/s (virtual), write p99 {}",
        load.writes_done,
        load.ops_per_sec(),
        fmt_ns(load.write_lat.quantile(0.99)),
    );

    // ---- skewed read/write phase (YCSB B: 95% reads) --------------------
    let mut spec = Spec::from_config(&cfg, Kind::B);
    spec.alpha = 0.99;
    let mut src = YcsbSource::new(spec, cfg.workload.clients);
    db.run(&mut src, cfg.workload.clients, None, false);
    let phase = std::mem::take(&mut db.metrics);
    println!(
        "[ycsb-B] {:.0} ops/s | read p50 {} p99 {} | HDD read share {:.1}% | {} migrations ({} XLA-scored scans)",
        phase.ops_per_sec(),
        fmt_ns(phase.read_lat.quantile(0.5)),
        fmt_ns(phase.read_lat.quantile(0.99)),
        phase.hdd_read_fraction() * 100.0,
        phase.migrations_cap + phase.migrations_pop,
        kernels.priority_calls.get(),
    );

    // ---- batched reads through the XLA bloom kernel ----------------------
    let batch: Vec<Vec<u8>> = (0..512u64)
        .map(|i| hhzs::ycsb::key_for(i * 97 % cfg.workload.load_objects, 24))
        .collect();
    let via_xla = db.multi_get(&batch);
    let bloom_calls = kernels.bloom_calls.get();
    // Parity check: the same keys through the native per-key path.
    db.xla = None;
    let native: Vec<Option<hhzs::wire::Payload>> = batch.iter().map(|k| db.get(k)).collect();
    anyhow::ensure!(via_xla == native, "XLA and native read paths must agree");
    let found = via_xla.iter().filter(|v| v.is_some()).count();
    println!(
        "[multi_get] 512 keys, {found} found | {bloom_calls} PJRT bloom dispatches | parity with native path OK"
    );

    println!(
        "[e2e] all layers composed: JAX/Pallas -> HLO text -> PJRT -> rust hot path ({:.1}s wall)",
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
