//! Sharded multi-engine demo: the same hybrid zoned substrate striped
//! over four independent LSM engines.
//!
//! Shows the shard tier end to end: the substrate lease layer splitting
//! the 20-zone SSD and the HDD pool, deterministic hash routing of the
//! synchronous API, the demand-proportional migration-budget split, and
//! merged metrics.
//!
//! Run: `cargo run --release --example sharded`

use hhzs::config::Config;
use hhzs::policy::HhzsPolicy;
use hhzs::report::fmt_bytes;
use hhzs::shard::ShardedEngine;
use hhzs::sim::fmt_ns;
use hhzs::ycsb::{key_for, value_for};

fn main() {
    let mut cfg = Config::paper_scaled(1024);
    cfg.shards = 4;
    let mut db = ShardedEngine::new(&cfg, |c| Box::new(HhzsPolicy::new(c.lsm.num_levels)));
    println!("substrate leases (shared 20-zone SSD + HDD pool):");
    for (s, e) in db.engines.iter().enumerate() {
        println!(
            "  shard {s}: {} SSD zones ({} pool), {} HDD zones, memtable {}",
            e.cfg.geometry.ssd_zones,
            e.cfg.geometry.wal_cache_zones,
            e.cfg.geometry.hdd_zones,
            fmt_bytes(e.cfg.lsm.memtable_size),
        );
    }

    println!("\nwriting 60,000 KV objects through the router...");
    for i in 0..60_000u64 {
        db.put_payload(&key_for(i, 24), value_for(i, 1000));
    }
    db.quiesce();

    for (s, e) in db.engines.iter().enumerate() {
        println!(
            "  shard {s}: {} writes, {} SSTs, {} flushes, {} compactions, clock {}",
            e.metrics.writes_done,
            e.version.total_ssts(),
            e.metrics.flushes,
            e.metrics.compactions,
            fmt_ns(e.now),
        );
    }

    // Reads route to the owning shard transparently.
    let k = key_for(31_337, 24);
    let v = db.get(&k).expect("key written above");
    assert_eq!(v, value_for(31_337, 1000));
    println!("\nget(key 31337) -> {} bytes from shard {}", v.len, db.router.route(&k));

    // The arbiter splits the global 4 MiB/s migration budget by demand.
    let rates = db.rebalance_migration_budgets();
    println!("migration budget split (global {:.1} MiB/s):", cfg.hhzs.migration_rate_bps / (1 << 20) as f64);
    for (s, r) in rates.iter().enumerate() {
        println!("  shard {s}: {:.2} MiB/s", r / (1 << 20) as f64);
    }

    let m = db.merged_metrics();
    println!(
        "\nmerged: {} ops, {} flushes, {} compactions, write p99 {}",
        m.ops_done,
        m.flushes,
        m.compactions,
        fmt_ns(m.write_lat.quantile(0.99)),
    );
}
