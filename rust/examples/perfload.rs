//! Perf-pass driver: a fresh heavy load (the wall-clock-dominant phase)
//! for `perf record` profiling. See EXPERIMENTS.md §Perf.
//!
//! Run: `cargo run --release --example perfload -- [quick|default|full]`
use hhzs::exp::common::*;

fn main() {
    let p = std::env::args().nth(1).unwrap_or_else(|| "default".into());
    let cfg = Profile::from_str(&p).expect("quick|default|full").config();
    let t0 = std::time::Instant::now();
    let (_, m) = load_fresh(&cfg, "HHZS", None, false);
    println!(
        "load {} objs: {:.2}s wall, {:.0} virt ops/s, {} flushes {} compactions, comp_rw={}MB",
        m.writes_done,
        t0.elapsed().as_secs_f64(),
        m.ops_per_sec(),
        m.flushes,
        m.compactions,
        (m.compaction_read_bytes + m.compaction_write_bytes) / 1_000_000
    );
}
