//! Hybrid-tiering showcase: the same YCSB workload under a basic static
//! placement (B3), SpanDB's AUTO, and HHZS — the Exp#1 story in miniature.
//!
//! Run: `cargo run --release --example ycsb_hybrid [-- <A|B|C|D|E|F>]`

use hhzs::exp::common::{load_and_run, Profile};
use hhzs::report::fmt_pct;
use hhzs::ycsb::Kind;

fn main() {
    let arg = std::env::args().nth(1).unwrap_or_else(|| "B".to_string());
    let kind = match arg.as_str() {
        "A" => Kind::A,
        "B" => Kind::B,
        "C" => Kind::C,
        "D" => Kind::D,
        "E" => Kind::E,
        "F" => Kind::F,
        other => {
            eprintln!("unknown workload {other:?}; use A..F");
            std::process::exit(2);
        }
    };
    let cfg = Profile::Quick.config();
    println!(
        "YCSB workload {arg}: {} records loaded, {} ops, alpha={}",
        cfg.workload.load_objects, cfg.workload.ops, cfg.workload.zipf_alpha
    );
    println!(
        "{:<6} {:>9} {:>10} {:>12} {:>11} {:>10}",
        "scheme", "OPS", "hdd-reads", "migrations", "cache-hits", "p99-read"
    );
    let mut baseline = None;
    for scheme in ["B3", "AUTO", "HHZS"] {
        let (engine, m) = load_and_run(&cfg, scheme, kind, cfg.workload.zipf_alpha);
        let tput = m.ops_per_sec();
        if scheme == "B3" {
            baseline = Some(tput);
        }
        println!(
            "{:<6} {:>9.0} {:>10} {:>12} {:>11} {:>10}",
            scheme,
            tput,
            fmt_pct(m.hdd_read_fraction()),
            m.migrations_cap + m.migrations_pop,
            m.ssd_cache_hits,
            hhzs::sim::fmt_ns(m.read_lat.quantile(0.99)),
        );
        if scheme == "HHZS" {
            let gain = (tput / baseline.unwrap() - 1.0) * 100.0;
            println!("        -> HHZS vs B3: {gain:+.1}% throughput");
            println!("        -> SSD share by level at end of run:");
            for (lvl, (ssd, all)) in engine.ssd_share_by_level().iter().enumerate() {
                if *all > 0 {
                    println!(
                        "             L{lvl}: {}",
                        fmt_pct(*ssd as f64 / *all as f64)
                    );
                }
            }
        }
    }
}
